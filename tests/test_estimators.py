"""Table-2 estimator correctness: unbiasedness, variance calibration, CI
coverage (paper §4.3 + Table 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimators as est_lib
from repro.core.types import AggOp


def _moments(values, rates, mask, groups, n_groups):
    return est_lib.grouped_moments(
        jnp.asarray(values), jnp.asarray(rates), jnp.asarray(mask),
        jnp.asarray(groups), n_groups)


def test_z_value():
    assert abs(est_lib.z_value(0.95) - 1.95996) < 1e-3
    assert abs(est_lib.z_value(0.99) - 2.57583) < 1e-3


def test_full_sample_is_exact():
    """rate = 1 everywhere → estimates are exact, variance 0."""
    rng = np.random.default_rng(0)
    x = rng.normal(10, 3, 1000).astype(np.float32)
    g = rng.integers(0, 4, 1000)
    mom = _moments(x, np.ones(1000), np.ones(1000, bool), g, 4)
    for agg, truth in [
        (AggOp.COUNT, np.bincount(g, minlength=4)),
        (AggOp.SUM, np.bincount(g, weights=x, minlength=4)),
        (AggOp.AVG, np.bincount(g, weights=x, minlength=4) / np.bincount(g, minlength=4)),
    ]:
        est = est_lib.estimate(agg, mom)
        np.testing.assert_allclose(np.asarray(est.value), truth, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(est.variance), 0.0, atol=1e-3)


@pytest.mark.parametrize("agg", [AggOp.COUNT, AggOp.SUM, AggOp.AVG])
def test_unbiased_and_ci_coverage(agg):
    """Monte Carlo over resamplings: HT estimates are unbiased and the 95% CI
    covers the truth at >= ~95% (the paper's §2 contract)."""
    rng = np.random.default_rng(42)
    n = 4000
    x = rng.gamma(2.0, 5.0, n).astype(np.float32)
    g = (rng.random(n) < 0.3).astype(np.int32)  # 2 groups
    freq = np.where(g == 0, (g == 0).sum(), (g == 1).sum()).astype(np.float32)
    k = 400.0
    rates = np.minimum(1.0, k / freq)
    truth = {
        AggOp.COUNT: np.bincount(g, minlength=2).astype(np.float64),
        AggOp.SUM: np.bincount(g, weights=x, minlength=2),
    }
    truth[AggOp.AVG] = truth[AggOp.SUM] / truth[AggOp.COUNT]

    trials = 400
    ests = np.zeros((trials, 2))
    cover = np.zeros((trials, 2), dtype=bool)
    for t in range(trials):
        u = rng.random(n)
        mask = u < rates  # Poisson stratified sample on g
        mom = _moments(x, rates, mask, g, 2)
        est = est_lib.estimate(agg, mom)
        stderr, lo, hi = est_lib.ci(est, 0.95)
        v = np.asarray(est.value)
        ests[t] = v
        cover[t] = (np.asarray(lo) <= truth[agg]) & (truth[agg] <= np.asarray(hi))
    bias = np.abs(ests.mean(0) - truth[agg]) / np.abs(truth[agg])
    assert np.all(bias < 0.02), f"bias {bias}"
    coverage = cover.mean(0)
    assert np.all(coverage > 0.90), f"coverage {coverage}"


def test_variance_scales_inverse_n():
    """Table 2: Var ∝ 1/n — doubling the cap halves the variance estimate."""
    rng = np.random.default_rng(7)
    n = 20_000
    x = rng.normal(50, 10, n).astype(np.float32)
    g = np.zeros(n, dtype=np.int32)
    freq = np.full(n, n, dtype=np.float32)
    vs = []
    for k in [500.0, 1000.0, 2000.0]:
        rates = np.minimum(1.0, k / freq)
        mask = rng.random(n) < rates
        mom = _moments(x, rates, mask, g, 1)
        est = est_lib.estimate(AggOp.SUM, mom)
        vs.append(float(est.variance[0]))
    assert 1.5 < vs[0] / vs[1] < 2.6
    assert 1.5 < vs[1] / vs[2] < 2.6


def test_required_n_projection():
    """ELP error profile: projected n meets the bound when re-run at that n."""
    rng = np.random.default_rng(3)
    n = 100_000
    x = rng.gamma(3.0, 2.0, n).astype(np.float32)
    g = np.zeros(n, dtype=np.int32)
    freq = np.full(n, n, dtype=np.float32)
    k_probe = 500.0
    rates = np.minimum(1.0, k_probe / freq)
    mask = rng.random(n) < rates
    mom = _moments(x, rates, mask, g, 1)
    est = est_lib.estimate(AggOp.AVG, mom)
    n_req = float(est_lib.required_n_for_error(AggOp.AVG, est, 0.01, 0.95, True)[0])
    assert n_req > float(est.n[0]), "1% bound needs more than the 500-row probe"
    # Re-run with a cap that yields ~n_req selected rows; check the bound.
    k2 = n_req * 1.05
    rates2 = np.minimum(1.0, k2 / freq)
    mask2 = rng.random(n) < rates2
    mom2 = _moments(x, rates2, mask2, g, 1)
    est2 = est_lib.estimate(AggOp.AVG, mom2)
    stderr, lo, hi = est_lib.ci(est2, 0.95)
    half = float(np.asarray(stderr)[0]) * est_lib.z_value(0.95)
    assert half <= 0.013 * float(est2.value[0]), "projection met the 1% bound"


def test_uniform_reduces_to_table2_count():
    """Uniform rate p: the HT (Poisson-design) count variance is exactly
    n_sel·(1-p)/p², and relates to Table 2's fixed-n SRS form N²c(1-c)/n by
    the design factor (1-p)/(1-c) — they agree in the small-selectivity limit
    (DESIGN.md 'assumption changes')."""
    rng = np.random.default_rng(11)
    n, p, c = 50_000, 0.05, 0.02  # small selectivity: designs agree
    sel_pred = rng.random(n) < c
    mask_sample = rng.random(n) < p
    mask = sel_pred & mask_sample
    rates = np.full(n, p, dtype=np.float32)
    mom = _moments(np.ones(n, np.float32), rates, mask, np.zeros(n, np.int32), 1)
    est = est_lib.estimate(AggOp.COUNT, mom)
    ht = float(est.variance[0])
    # exact Poisson-design closed form
    np.testing.assert_allclose(ht, mask.sum() * (1 - p) / p ** 2, rtol=1e-5)
    # Table-2 SRS form matches within the (1-p)/(1-c) design factor
    n_sample = mask_sample.sum()
    c_hat = mask.sum() / n_sample
    table2 = (n ** 2) * c_hat * (1 - c_hat) / n_sample
    ratio = ht / table2
    expect_ratio = (1 - p) / (1 - c_hat)
    assert abs(ratio - expect_ratio) / expect_ratio < 0.10, (ratio, expect_ratio)
