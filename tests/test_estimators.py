"""Table-2 estimator correctness: unbiasedness, variance calibration, CI
coverage (paper §4.3 + Table 2) — plus the estimator-under-mutation
regression: every statistic a mutated family feeds downstream must match a
clean from-scratch rebuild."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import estimators as est_lib
from repro.core.types import AggOp


def _moments(values, rates, mask, groups, n_groups):
    return est_lib.grouped_moments(
        jnp.asarray(values), jnp.asarray(rates), jnp.asarray(mask),
        jnp.asarray(groups), n_groups)


def test_z_value():
    assert abs(est_lib.z_value(0.95) - 1.95996) < 1e-3
    assert abs(est_lib.z_value(0.99) - 2.57583) < 1e-3


def test_full_sample_is_exact():
    """rate = 1 everywhere → estimates are exact, variance 0."""
    rng = np.random.default_rng(0)
    x = rng.normal(10, 3, 1000).astype(np.float32)
    g = rng.integers(0, 4, 1000)
    mom = _moments(x, np.ones(1000), np.ones(1000, bool), g, 4)
    for agg, truth in [
        (AggOp.COUNT, np.bincount(g, minlength=4)),
        (AggOp.SUM, np.bincount(g, weights=x, minlength=4)),
        (AggOp.AVG, np.bincount(g, weights=x, minlength=4) / np.bincount(g, minlength=4)),
    ]:
        est = est_lib.estimate(agg, mom)
        np.testing.assert_allclose(np.asarray(est.value), truth, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(est.variance), 0.0, atol=1e-3)


@pytest.mark.parametrize("agg", [AggOp.COUNT, AggOp.SUM, AggOp.AVG])
def test_unbiased_and_ci_coverage(agg):
    """Monte Carlo over resamplings: HT estimates are unbiased and the 95% CI
    covers the truth at >= ~95% (the paper's §2 contract)."""
    rng = np.random.default_rng(42)
    n = 4000
    x = rng.gamma(2.0, 5.0, n).astype(np.float32)
    g = (rng.random(n) < 0.3).astype(np.int32)  # 2 groups
    freq = np.where(g == 0, (g == 0).sum(), (g == 1).sum()).astype(np.float32)
    k = 400.0
    rates = np.minimum(1.0, k / freq)
    truth = {
        AggOp.COUNT: np.bincount(g, minlength=2).astype(np.float64),
        AggOp.SUM: np.bincount(g, weights=x, minlength=2),
    }
    truth[AggOp.AVG] = truth[AggOp.SUM] / truth[AggOp.COUNT]

    trials = 400
    ests = np.zeros((trials, 2))
    cover = np.zeros((trials, 2), dtype=bool)
    for t in range(trials):
        u = rng.random(n)
        mask = u < rates  # Poisson stratified sample on g
        mom = _moments(x, rates, mask, g, 2)
        est = est_lib.estimate(agg, mom)
        stderr, lo, hi = est_lib.ci(est, 0.95)
        v = np.asarray(est.value)
        ests[t] = v
        cover[t] = (np.asarray(lo) <= truth[agg]) & (truth[agg] <= np.asarray(hi))
    bias = np.abs(ests.mean(0) - truth[agg]) / np.abs(truth[agg])
    assert np.all(bias < 0.02), f"bias {bias}"
    coverage = cover.mean(0)
    assert np.all(coverage > 0.90), f"coverage {coverage}"


def test_variance_scales_inverse_n():
    """Table 2: Var ∝ 1/n — doubling the cap halves the variance estimate."""
    rng = np.random.default_rng(7)
    n = 20_000
    x = rng.normal(50, 10, n).astype(np.float32)
    g = np.zeros(n, dtype=np.int32)
    freq = np.full(n, n, dtype=np.float32)
    vs = []
    for k in [500.0, 1000.0, 2000.0]:
        rates = np.minimum(1.0, k / freq)
        mask = rng.random(n) < rates
        mom = _moments(x, rates, mask, g, 1)
        est = est_lib.estimate(AggOp.SUM, mom)
        vs.append(float(est.variance[0]))
    assert 1.5 < vs[0] / vs[1] < 2.6
    assert 1.5 < vs[1] / vs[2] < 2.6


def test_required_n_projection():
    """ELP error profile: projected n meets the bound when re-run at that n."""
    rng = np.random.default_rng(3)
    n = 100_000
    x = rng.gamma(3.0, 2.0, n).astype(np.float32)
    g = np.zeros(n, dtype=np.int32)
    freq = np.full(n, n, dtype=np.float32)
    k_probe = 500.0
    rates = np.minimum(1.0, k_probe / freq)
    mask = rng.random(n) < rates
    mom = _moments(x, rates, mask, g, 1)
    est = est_lib.estimate(AggOp.AVG, mom)
    n_req = float(est_lib.required_n_for_error(AggOp.AVG, est, 0.01, 0.95, True)[0])
    assert n_req > float(est.n[0]), "1% bound needs more than the 500-row probe"
    # Re-run with a cap that yields ~n_req selected rows; check the bound.
    k2 = n_req * 1.05
    rates2 = np.minimum(1.0, k2 / freq)
    mask2 = rng.random(n) < rates2
    mom2 = _moments(x, rates2, mask2, g, 1)
    est2 = est_lib.estimate(AggOp.AVG, mom2)
    stderr, lo, hi = est_lib.ci(est2, 0.95)
    half = float(np.asarray(stderr)[0]) * est_lib.z_value(0.95)
    assert half <= 0.013 * float(est2.value[0]), "projection met the 1% bound"


def _prefix_inputs(fam, k, value_col="SessionTime", group_col="OS"):
    """Canonical-order scan inputs for the prefix S(φ, k) of a family."""
    from test_mutations import _canon
    order = _canon(fam)
    n = int(np.searchsorted(fam.entry_key_host[order], np.float32(k), "left"))
    idx = order[:n]
    x = fam.host_column(value_col)[idx].astype(np.float32)
    freq = np.asarray(fam.freq)[idx]
    rates = np.minimum(1.0, np.float32(k) / freq)
    g = fam.host_column(group_col)[idx].astype(np.int32)
    return x, rates, g


def _subsampling_quantile_band(x, w, q=0.5, n_sub=32):
    """Variational-subsampling weighted-quantile band (2.5/50/97.5): rows are
    hash-partitioned into n_sub DISJOINT subsamples (the same multiplicative
    hash the executor's subsample_codes uses), each contributing one weighted
    quantile replicate. Replaces the old bootstrap band — one pass over the
    data, fully deterministic, no RNG state to thread through tests."""
    sub = ((np.arange(len(x), dtype=np.uint64) * np.uint64(2654435761))
           >> np.uint64(7)) % np.uint64(n_sub)
    out = []
    for j in range(n_sub):
        m = sub == j
        if not m.any():
            continue
        xx, ww = x[m], w[m]
        s = np.argsort(xx, kind="stable")
        cw = np.cumsum(ww[s])
        out.append(xx[s][min(np.searchsorted(cw, q * cw[-1]), len(xx) - 1)])
    return np.percentile(out, [2.5, 50.0, 97.5])


def test_mutated_family_estimators_match_clean_rebuild():
    """Estimator-under-mutation regression: after a delete/update/append
    churn, ALL SEVEN scan statistics (the GroupedMoments leaves), the
    closed-form estimates + CIs for every aggregate, the histogram quantile,
    and subsampling quantile bands computed from the mutated family match a
    clean from-scratch rebuild within float tolerance, at every resolution."""
    from test_mutations import MutationMirror, _apply_op, _mk_db
    from repro.core import executor as exec_lib
    mirror = MutationMirror(_mk_db(n0=3000))
    for op in [("delete", "City", 0), ("append", 250, 77),
               ("update", "City", 2, 1), ("delete", "OS", 1),
               ("append", 120, 78)]:
        _apply_op(mirror, op)
    fam = mirror.db.families["s"][("City",)]
    oracle = mirror.oracle(("City",))
    n_groups = mirror.db.tables["s"].cardinality("OS")

    for k in fam.ks:
        moms, quants, boots = [], [], []
        for f in (fam, oracle):
            x, rates, g = _prefix_inputs(f, k)
            mom = est_lib.grouped_moments(
                jnp.asarray(x), jnp.asarray(rates),
                jnp.ones(len(x), bool), jnp.asarray(g), n_groups)
            moms.append(mom)
            quants.append(exec_lib.grouped_quantile(
                jnp.asarray(x), jnp.asarray(1.0 / rates), jnp.asarray(g),
                n_groups, 0.5))
            boots.append(_subsampling_quantile_band(
                x.astype(np.float64), 1.0 / rates.astype(np.float64)))
        # all seven sufficient statistics, leaf by leaf
        leaves_a = jax.tree.leaves(moms[0])
        leaves_b = jax.tree.leaves(moms[1])
        assert len(leaves_a) == 7
        for a, b in zip(leaves_a, leaves_b):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)
        # closed-form estimates + CIs for the additive/ratio aggregates
        for agg in (AggOp.COUNT, AggOp.SUM, AggOp.AVG):
            ea = est_lib.estimate(agg, moms[0])
            eb = est_lib.estimate(agg, moms[1])
            for fa, fb in [(ea.value, eb.value), (ea.n, eb.n)]:
                np.testing.assert_allclose(np.asarray(fa), np.asarray(fb),
                                           rtol=1e-6, atol=1e-6)
            for ca, cb in zip(est_lib.ci(ea, 0.95), est_lib.ci(eb, 0.95)):
                np.testing.assert_allclose(np.asarray(ca), np.asarray(cb),
                                           rtol=1e-5, atol=1e-5)
        # histogram quantile + its Table-2 density, then the closed-form CI
        (qa, da), (qb, db_) = quants
        np.testing.assert_allclose(np.asarray(qa), np.asarray(qb),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(da), np.asarray(db_),
                                   rtol=1e-6, atol=1e-6)
        eqa = est_lib.estimate(AggOp.QUANTILE, moms[0], quantile_value=qa,
                               quantile_density=da, q=0.5)
        eqb = est_lib.estimate(AggOp.QUANTILE, moms[1], quantile_value=qb,
                               quantile_density=db_, q=0.5)
        for ca, cb in zip(est_lib.ci(eqa, 0.95), est_lib.ci(eqb, 0.95)):
            np.testing.assert_allclose(np.asarray(ca), np.asarray(cb),
                                       rtol=1e-5, atol=1e-5)
        # subsampling quantile bands (same hash partition, same rows)
        np.testing.assert_allclose(boots[0], boots[1], rtol=1e-7)


def test_uniform_reduces_to_table2_count():
    """Uniform rate p: the HT (Poisson-design) count variance is exactly
    n_sel·(1-p)/p², and relates to Table 2's fixed-n SRS form N²c(1-c)/n by
    the design factor (1-p)/(1-c) — they agree in the small-selectivity limit
    (DESIGN.md 'assumption changes')."""
    rng = np.random.default_rng(11)
    n, p, c = 50_000, 0.05, 0.02  # small selectivity: designs agree
    sel_pred = rng.random(n) < c
    mask_sample = rng.random(n) < p
    mask = sel_pred & mask_sample
    rates = np.full(n, p, dtype=np.float32)
    mom = _moments(np.ones(n, np.float32), rates, mask, np.zeros(n, np.int32), 1)
    est = est_lib.estimate(AggOp.COUNT, mom)
    ht = float(est.variance[0])
    # exact Poisson-design closed form
    np.testing.assert_allclose(ht, mask.sum() * (1 - p) / p ** 2, rtol=1e-5)
    # Table-2 SRS form matches within the (1-p)/(1-c) design factor
    n_sample = mask_sample.sum()
    c_hat = mask.sum() / n_sample
    table2 = (n ** 2) * c_hat * (1 - c_hat) / n_sample
    ratio = ht / table2
    expect_ratio = (1 - p) / (1 - c_hat)
    assert abs(ratio - expect_ratio) / expect_ratio < 0.10, (ratio, expect_ratio)
