"""Incremental ingestion + delta-based sample maintenance (§3.2.3/§4.5).

The load-bearing property: after ANY sequence of appends, the incrementally
merged family is BIT-IDENTICAL to a from-scratch rebuild fed the same
per-row units (the host oracle) — nested prefixes, exact HT rates, identical
query estimates. Plus cache-validity: appends must never be answered by a
stale compiled program.
"""
import numpy as np
import pytest

from repro.core import (AggOp, Atom, BlinkDB, CmpOp, Conjunction, EngineConfig,
                        ErrorBound, Predicate, Query, QueryTemplate)
from repro.core import sampling as samp
from repro.core import table as table_lib
from repro.core.engine import _union_answers
from repro.core.maintenance import MaintenanceConfig, SampleMaintainer
from repro.core.types import GroupResult
from repro.data import synth


# ------------------------------------------------------------- table layer

def test_append_extends_dictionaries_without_recoding():
    tbl = table_lib.from_columns("t", {
        "key": np.array(["b", "a", "b"]), "x": np.array([1., 2., 3.],
                                                        np.float32)})
    old_codes = np.asarray(tbl.columns["key"]).copy()
    old_dict = tbl.dictionaries["key"].copy()
    delta = tbl.append({"key": np.array(["c", "a"]),
                        "x": np.array([4., 5.], np.float32)})
    # existing rows keep their codes; the dictionary only grows at the tail
    # (tbl.columns refreshes its lazily-stale device copy on access)
    np.testing.assert_array_equal(np.asarray(tbl.columns["key"]),
                                  np.concatenate([old_codes, [2, 0]]))
    np.testing.assert_array_equal(tbl.dictionaries["key"][:2], old_dict)
    assert list(delta.new_dict_values["key"]) == ["c"]
    assert tbl.cardinality("key") == 3
    assert tbl.n_rows == 5
    assert tbl.encode_value("key", "c") == 2
    assert delta.start_row == 3 and delta.n_rows == 2
    np.testing.assert_array_equal(delta.columns["key"], [2, 0])


def test_append_rejects_schema_mismatch_without_partial_mutation():
    tbl = table_lib.from_columns("t", {"key": np.array(["a"]),
                                       "x": np.array([1.], np.float32)})
    with pytest.raises(ValueError, match="delta columns"):
        tbl.append({"key": np.array(["a"])})
    # ragged delta with a NEW categorical value: the rejection must not
    # leave a phantom dictionary entry / inflated cardinality behind
    with pytest.raises(ValueError, match="length"):
        tbl.append({"key": np.array(["a", "b"]),
                    "x": np.array([1.], np.float32)})
    # a measure that cannot cast to f32 must also reject atomically
    with pytest.raises(ValueError):
        tbl.append({"key": np.array(["b"]), "x": np.array(["oops"])})
    assert tbl.n_rows == 1
    assert tbl.cardinality("key") == 1
    np.testing.assert_array_equal(tbl.dictionaries["key"], ["a"])


def test_map_codes_stable_preserves_ids_and_extends():
    keys = np.array([[0, 1], [2, 0]], np.int32)
    mat = np.array([[2, 0], [3, 3], [0, 1], [3, 3]], np.int32)
    codes, new_keys = table_lib.map_codes_stable(mat, keys)
    np.testing.assert_array_equal(codes, [1, 2, 0, 2])
    np.testing.assert_array_equal(new_keys[:2], keys)
    np.testing.assert_array_equal(new_keys[2], [3, 3])
    freqs = table_lib.extend_frequencies(np.array([10, 20]), codes, 3)
    np.testing.assert_array_equal(freqs, [11, 21, 2])


# --------------------------------------------- merge == from-scratch oracle

def _random_appends(base_n, n_appends, rng, **kw):
    raws = [synth.sessions_table(base_n, seed=int(rng.integers(1e6)), **kw)]
    for _ in range(n_appends):
        d = int(rng.integers(200, 2500))
        raws.append(synth.sessions_table(d, seed=int(rng.integers(1e6)),
                                         **kw))
    return raws


def _assert_families_identical(fam, oracle):
    """Exact equality up to entry-key TIES: the merged family and the oracle
    contain the same rows with the same keys/rates, but exact f32 entry-key
    collisions (likely at 1e4+ rows) may order differently under the two
    stable sorts. Queries are order-invariant within a prefix, so compare
    under a tie-canonical permutation (lexsort by unit within key)."""
    assert fam.n_rows == oracle.n_rows
    assert fam.prefix_sizes == oracle.prefix_sizes
    np.testing.assert_array_equal(fam.entry_key_host, oracle.entry_key_host)

    from test_mutations import _canon   # (ek, row_id): one shared total order
    pa, pb = _canon(fam), _canon(oracle)
    np.testing.assert_array_equal(np.asarray(fam.freq)[pa],
                                  np.asarray(oracle.freq)[pb])
    np.testing.assert_array_equal(np.asarray(fam.unit)[pa],
                                  np.asarray(oracle.unit)[pb])
    np.testing.assert_array_equal(fam.row_ids[pa], oracle.row_ids[pb])
    for c in fam.columns:
        np.testing.assert_array_equal(np.asarray(fam.columns[c])[pa],
                                      np.asarray(oracle.columns[c])[pb])
    np.testing.assert_array_equal(np.sort(fam.stratum_freqs),
                                  np.sort(oracle.stratum_freqs))
    # append-only: live counts never diverge from the inclusion freqs
    np.testing.assert_array_equal(fam.live_freqs, fam.stratum_freqs)
    np.testing.assert_array_equal(np.sort(fam.live_freqs),
                                  np.sort(oracle.live_freqs))


@pytest.mark.parametrize("case_seed", [0, 1, 2])
def test_merged_family_matches_oracle(case_seed):
    """Property test: after N random appends (including ones that introduce
    new strata), the merged family equals build_family on the appended table
    with the concatenated unit segments — exactly, not approximately."""
    rng = np.random.default_rng(case_seed)
    raws = _random_appends(12_000, 3, rng, n_cities=180 + 30 * case_seed)
    seed = 40 + case_seed
    tbl = table_lib.from_columns("s", raws[0])
    fam = samp.build_family(tbl, ("City", "OS"), k1=300.0, m=3, seed=seed)
    units = [samp.base_units(tbl.n_rows, seed)]
    for epoch, raw in enumerate(raws[1:], start=1):
        delta = tbl.append(raw)
        du = samp.delta_units(delta.n_rows, seed, epoch)
        units.append(du)
        fam, block = samp.merge_family(fam, delta.columns, du)
        assert block.n_rows <= delta.n_rows
    oracle = samp.build_family(tbl, ("City", "OS"), k1=300.0, m=3,
                               units=np.concatenate(units))
    _assert_families_identical(fam, oracle)


def test_merged_uniform_family_matches_oracle():
    rng = np.random.default_rng(7)
    raws = _random_appends(10_000, 3, rng)
    seed, frac = 9, 0.3
    tbl = table_lib.from_columns("s", raws[0])
    fam = samp.build_uniform_family(tbl, frac, m=3, seed=seed)
    units = [samp.base_units(tbl.n_rows, seed, uniform=True)]
    for epoch, raw in enumerate(raws[1:], start=1):
        delta = tbl.append(raw)
        du = samp.delta_units(delta.n_rows, seed, epoch, uniform=True)
        units.append(du)
        fam, _ = samp.merge_family(fam, delta.columns, du,
                                   new_k1=frac * tbl.n_rows)
    oracle = samp.build_uniform_family(tbl, frac, m=3,
                                       units=np.concatenate(units))
    _assert_families_identical(fam, oracle)
    np.testing.assert_allclose(fam.ks, oracle.ks, rtol=1e-12)


def test_merged_family_invariants_and_exact_ht_rates():
    """Nesting, sortedness and EXACT Horvitz–Thompson rates after merges:
    rate(row, K) must equal min(1, K / F_new) with F_new the recounted
    full-table stratum frequency."""
    tbl = table_lib.from_columns("s", synth.sessions_table(15_000, seed=3))
    fam = samp.build_family(tbl, ("City",), k1=250.0, m=3, seed=5)
    for epoch in (1, 2):
        delta = tbl.append(synth.sessions_table(2_000, seed=50 + epoch))
        fam, _ = samp.merge_family(
            fam, delta.columns, samp.delta_units(delta.n_rows, 5, epoch))
    ek = fam.entry_key_host
    assert np.all(np.diff(ek) >= 0)
    assert fam.prefix_sizes[0] == fam.n_rows
    assert list(fam.prefix_sizes) == sorted(fam.prefix_sizes, reverse=True)
    for k, n in zip(fam.ks, fam.prefix_sizes):
        assert np.all(ek[:n] < k)
        if n < fam.n_rows:
            assert ek[n] >= k
    # freq column must match a full recount of the appended table
    codes, _ = table_lib.combined_codes(tbl, ("City",))
    full = table_lib.stratum_frequencies(codes, int(codes.max()) + 1)
    city = np.asarray(fam.columns["City"])
    np.testing.assert_array_equal(np.asarray(fam.freq),
                                  full[city].astype(np.float32))
    for k in fam.ks:
        np.testing.assert_allclose(np.asarray(fam.rate(k)),
                                   np.minimum(1.0, k / full[city]), rtol=1e-6)


# ---------------------------------------------------------- engine parity

def _engine_with_family(tbl, seed=3):
    db = BlinkDB(EngineConfig(k1=600.0, m=3, seed=seed))
    db.register_table("s", tbl)
    db.add_family("s", ("City",))
    db.add_family("s", ())
    return db


def test_append_rows_matches_oracle_engine():
    """Acceptance: queries after BlinkDB.append_rows answer identically
    (within fp tolerance) to an engine whose families were rebuilt from
    scratch on the appended table (same unit segments)."""
    seed = 3
    tbl = table_lib.from_columns("s", synth.sessions_table(25_000, seed=11))
    db = _engine_with_family(tbl, seed)
    frac = db.config.uniform_fraction
    units = [samp.base_units(tbl.n_rows, seed)]
    uunits = [samp.base_units(tbl.n_rows, seed, uniform=True)]
    for epoch in (1, 2):
        raw = synth.sessions_table(1_200 * epoch, seed=70 + epoch)
        db.append_rows("s", raw)
        d = len(raw["City"])
        units.append(samp.delta_units(d, seed, epoch))
        uunits.append(samp.delta_units(d, seed, epoch, uniform=True))

    # Oracle engine: same (appended) table object, families rebuilt from
    # scratch with the concatenated unit segments.
    db2 = BlinkDB(EngineConfig(k1=600.0, m=3, seed=seed))
    db2.register_table("s", db.tables["s"])
    db2.families["s"][("City",)] = samp.build_family(
        db.tables["s"], ("City",), 600.0, m=3, units=np.concatenate(units))
    db2.families["s"][()] = samp.build_uniform_family(
        db.tables["s"], frac, m=3, units=np.concatenate(uunits))

    cities = db.tables["s"].dictionaries["City"]
    queries = [
        Query("s", AggOp.COUNT,
              predicate=Predicate.where(Atom("City", CmpOp.EQ, cities[1])),
              bound=ErrorBound(0.1)),
        Query("s", AggOp.AVG, "SessionTime", group_by=("OS",),
              bound=ErrorBound(0.1)),
        Query("s", AggOp.SUM, "Bitrate",
              predicate=Predicate.where(Atom("City", CmpOp.EQ, cities[0]))),
        Query("s", AggOp.QUANTILE, "SessionTime", quantile=0.5,
              bound=ErrorBound(0.1)),
    ]
    for q in queries:
        a, b = db.query(q), db2.query(q)
        assert a.sample_phi == b.sample_phi
        ka = {g.key: g for g in a.groups}
        kb = {g.key: g for g in b.groups}
        assert ka.keys() == kb.keys()
        for key in ka:
            np.testing.assert_allclose(ka[key].estimate, kb[key].estimate,
                                       rtol=1e-5)
            np.testing.assert_allclose(ka[key].stderr, kb[key].stderr,
                                       rtol=1e-4, atol=1e-9)


def test_append_not_answered_by_stale_programs():
    """Cache validity: a warm compiled program must see appended rows.
    A stratum kept entirely (F < K) answers COUNT exactly, so the estimate
    after the append must equal the NEW exact count — a stale program would
    return the old one."""
    tbl = table_lib.from_columns("s", synth.sessions_table(20_000, seed=2))
    db = _engine_with_family(tbl)
    cities = db.tables["s"].dictionaries["City"]
    # find a city with a small stratum (fully contained: F << k1=600)
    counts = np.bincount(np.asarray(tbl.columns["City"]),
                         minlength=len(cities))
    code = int(np.argmin(np.where(counts > 0, counts, 1 << 30)))
    city = cities[code]
    q = Query("s", AggOp.COUNT,
              predicate=Predicate.where(Atom("City", CmpOp.EQ, city)),
              bound=ErrorBound(0.1))
    a1 = db.query(q)
    assert abs(a1.groups[0].estimate - counts[code]) < 1e-3
    progs = dict(db._programs)

    # append 50 more rows of exactly that city
    raw = synth.sessions_table(50, seed=9)
    raw["City"] = np.full(50, city, dtype=raw["City"].dtype)
    db.append_rows("s", raw)
    # same compiled programs survive the in-place merge...
    assert all(db._programs.get(k) is v for k, v in progs.items())
    # ...and answer with the appended data, not the stale prefix
    a2 = db.query(q)
    assert abs(a2.groups[0].estimate - (counts[code] + 50)) < 1e-3
    exact = db.exact_query(q)
    assert abs(exact.groups[0].estimate - (counts[code] + 50)) < 1e-6


def test_append_outgrowing_padding_restripes_and_stays_correct():
    """A delta larger than the stripe headroom forces a compacting restripe
    (programs recompile) — answers must stay exact for contained strata."""
    tbl = table_lib.from_columns("s", synth.sessions_table(8_000, seed=4))
    db = _engine_with_family(tbl)
    q = Query("s", AggOp.COUNT, bound=ErrorBound(0.2))
    db.query(q)  # warm + stripe
    report = db.append_rows("s", synth.sessions_table(6_000, seed=5))
    assert ("City",) in report.restriped or () in report.restriped
    got = db.query(Query("s", AggOp.COUNT, group_by=("OS",),
                         bound=ErrorBound(0.2)))
    exact = db.exact_query(Query("s", AggOp.COUNT, group_by=("OS",)))
    ex = {g.key: g.estimate for g in exact.groups}
    for g in got.groups:
        assert abs(g.estimate - ex[g.key]) / ex[g.key] < 0.2


def test_append_new_dictionary_value_is_queryable():
    tbl = table_lib.from_columns("s", synth.sessions_table(10_000, seed=6))
    db = _engine_with_family(tbl)
    db.query(Query("s", AggOp.COUNT, group_by=("City",),
                   bound=ErrorBound(0.2)))  # warm with the OLD cardinality
    raw = synth.sessions_table(300, seed=8)
    raw["City"] = np.array(["cityNEW"] * 300, dtype=raw["City"].dtype)
    db.append_rows("s", raw)
    # no bound -> largest K -> the 300-row stratum (< k1) is fully contained
    q = Query("s", AggOp.COUNT,
              predicate=Predicate.where(Atom("City", CmpOp.EQ, "cityNEW")))
    ans = db.query(q)
    assert abs(ans.groups[0].estimate - 300) < 1e-3
    # and the new value shows up as a GROUP BY key
    grouped = db.query(Query("s", AggOp.COUNT, group_by=("City",),
                             bound=ErrorBound(0.2)))
    assert ("cityNEW",) in {g.key for g in grouped.groups}


def test_public_append_strips_gathered_join_columns():
    """tbl.append on the PUBLIC table API must drop gathered "dim.col"
    columns — leaving them at the old length corrupts the exact/join path."""
    import jax.numpy as jnp
    tbl = table_lib.from_columns("t", {"key": np.array(["a", "b"]),
                                       "x": np.array([1., 2.], np.float32)})
    tbl.columns["dim.col"] = jnp.zeros(2, jnp.float32)
    tbl.append({"key": np.array(["a"]), "x": np.array([3.], np.float32)})
    assert "dim.col" not in tbl.columns
    assert all(len(np.asarray(tbl.columns[c])) == 3 for c in ("key", "x"))


def test_replacement_with_recoded_dictionary_rebuilds_families():
    """A replacement table whose dictionary gained a value that sorts FIRST
    shifts every code; surviving families hold old codes and MUST rebuild
    even though the distribution (and hence drift) is unchanged."""
    raw = synth.sessions_table(12_000, seed=4)
    tbl = table_lib.from_columns("s", raw)
    db = BlinkDB(EngineConfig(k1=400.0, m=3, seed=2))
    db.register_table("s", tbl)
    templates = [QueryTemplate(frozenset({"City"}), 1.0)]
    db.build_samples("s", templates, storage_budget_fraction=0.5)
    db.add_family("s", ("City",))
    maint = SampleMaintainer(db, "s", templates,
                             MaintenanceConfig(drift_threshold=0.05))
    extra = {k: v[:20] for k, v in synth.sessions_table(100, seed=5).items()}
    extra["City"] = np.full(20, "aaa")   # sorts before every "cityNNN"
    raw2 = {k: np.concatenate([raw[k], extra[k]]) for k in raw}
    tbl2 = table_lib.from_columns("s", raw2)
    report = maint.run_epoch(new_table=tbl2)
    # EVERY family that survived selection must have been rebuilt (despite
    # ~zero drift): surviving rows are coded under the replaced dictionary.
    assert sorted(report["rebuilt"]) == sorted(db.families["s"]), report
    city = raw["City"][0]   # the Zipf-top city: a one-code shift would
    q = Query("s", AggOp.COUNT,          # return its much smaller neighbour
              predicate=Predicate.where(Atom("City", CmpOp.EQ, city)))
    got = db.query(q).groups[0].estimate
    exact = db.exact_query(q).groups[0].estimate
    assert abs(got - exact) <= max(20.0, 0.15 * exact), (got, exact)


def test_run_epoch_delta_merges_or_rebuilds_on_drift():
    tbl = table_lib.from_columns("s", synth.sessions_table(15_000, seed=1,
                                                           city_s=1.4))
    db = BlinkDB(EngineConfig(k1=400.0, m=3, seed=2))
    db.register_table("s", tbl)
    templates = [QueryTemplate(frozenset({"City"}), 1.0)]
    db.build_samples("s", templates, storage_budget_fraction=0.5)
    db.add_family("s", ("City",))
    maint = SampleMaintainer(db, "s", templates,
                             MaintenanceConfig(drift_threshold=0.05))
    seed_before = db.config.seed
    low = maint.run_epoch(delta=synth.sessions_table(800, seed=21,
                                                     city_s=1.4))
    assert low["rebuilt"] == [] and low["objective"] is None
    assert ("City",) in low["merged"]
    high = maint.run_epoch(delta=synth.sessions_table(15_000, seed=22,
                                                      city_s=0.2))
    assert high["drift"][("City",)] > 0.05
    assert ("City",) in high["rebuilt"]
    assert db.config.seed == seed_before, \
        "run_epoch must not mutate the shared EngineConfig.seed"
    ans = db.query(Query("s", AggOp.COUNT, group_by=("OS",),
                         bound=ErrorBound(0.2)))
    assert ans.groups


def test_append_with_new_fk_value_joins_correctly():
    """A fact append whose delta introduces a NEW foreign-key value must
    refresh the cached fk→dim-row map — a stale map (sized by the old fk
    dictionary) would clamp-join the new rows to an arbitrary dim row."""
    from repro.core.joins import Join
    fact = table_lib.from_columns("fact", {
        "UserId": np.array(["u0", "u1", "u2"] * 100),
        "x": np.ones(300, np.float32)})
    dim = table_lib.from_columns("users", {
        "UserId": np.array(["u0", "u1", "u2", "u9"]),
        "Country": np.array(["US", "US", "DE", "FR"])})
    db = BlinkDB(EngineConfig(k1=500.0, m=2))
    db.register_table("fact", fact)
    db.register_table("users", dim)
    db.add_family("fact", ("UserId",))
    db.add_family("fact", ())
    q = Query("fact", AggOp.COUNT, group_by=("users.Country",),
              joins=(Join("users", "UserId", "UserId"),))
    ex1 = {g.key: g.estimate for g in db.exact_query(q).groups}
    assert ex1 == {("US",): 200.0, ("DE",): 100.0}  # warms the fk map
    db.append_rows("fact", {"UserId": np.array(["u9"] * 50),
                            "x": np.zeros(50, np.float32)})
    ex2 = {g.key: g.estimate for g in db.exact_query(q).groups}
    assert ex2 == {("US",): 200.0, ("DE",): 100.0, ("FR",): 50.0}
    # sampled path: every stratum is below k1 -> exact counts
    ans = {g.key: g.estimate for g in db.query(q).groups}
    assert ans == ex2


# ------------------------------------------------------- satellite fixes

def test_union_answers_copies_singleton_groups():
    g = GroupResult(("a",), 10.0, 2.0, 1.0, 2.0, 5.0, False)
    from repro.core.types import Answer
    q = Query("t", AggOp.SUM, "x")
    a = Answer(q, [g], ("x",), 1.0, 10, 100, 0.0, 0.95)
    out = _union_answers(q, [a])
    assert out.groups[0] is not g, "singleton group must be copied"
    assert (g.ci_low, g.ci_high) == (1.0, 2.0), \
        "sub-answer GroupResult mutated in place"
    assert out.groups[0].ci_low != 1.0  # recomputed from stderr


def test_disjunctive_nonadditive_aggregates_rejected():
    tbl = table_lib.from_columns("s", synth.sessions_table(5_000, seed=3))
    db = _engine_with_family(tbl)
    pred = Predicate((
        Conjunction((Atom("OS", CmpOp.EQ, "os0"),)),
        Conjunction((Atom("OS", CmpOp.EQ, "os1"),)),
    ))
    for agg, vc in ((AggOp.AVG, "SessionTime"),
                    (AggOp.QUANTILE, "SessionTime")):
        q = Query("s", agg, vc, predicate=pred, bound=ErrorBound(0.2))
        with pytest.raises(ValueError, match="additive"):
            db.query(q)
        with pytest.raises(ValueError, match="additive"):
            db.query_batch([q])
    # additive aggregates still work
    ans = db.query(Query("s", AggOp.COUNT, predicate=pred,
                         bound=ErrorBound(0.2)))
    assert ans.groups


def test_prefix_for_k_uses_host_mirror():
    tbl = table_lib.from_columns("s", synth.sessions_table(8_000, seed=5))
    fam = samp.build_family(tbl, ("City",), k1=300.0, m=3, seed=1)
    assert isinstance(fam.entry_key_host, np.ndarray)
    want = int(np.searchsorted(np.asarray(fam.entry_key), fam.ks[1]))
    assert fam.prefix_for_k(fam.ks[1]) == want
    # merge keeps the mirror in sync
    delta = tbl.append(synth.sessions_table(500, seed=6))
    fam, _ = samp.merge_family(fam, delta.columns,
                               samp.delta_units(500, 1, 1))
    np.testing.assert_array_equal(fam.entry_key_host,
                                  np.asarray(fam.entry_key))
