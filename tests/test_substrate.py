"""Substrate tests: checkpointing, fault tolerance, data pipeline, optimizer,
executor striping, serve engine."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.core import executor as exec_lib
from repro.core import sampling as samp_lib
from repro.core import table as table_lib
from repro.data import synth
from repro.data.tokens import DataConfig, SyntheticTokenStream
from repro.fault.supervisor import (Heartbeat, RetryLoop, StragglerPolicy,
                                    elastic_plan)
from repro.obs.clock import now_s
from repro.train import optim as optim_lib


# ----------------------------------------------------------- checkpointing

def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        state = {"a": jnp.arange(10.0), "nested": {"b": jnp.ones((3, 3))}}
        for step in (10, 20, 30):
            mgr.save(step, jax.tree.map(lambda x: x * step, state))
        assert mgr.all_steps() == [20, 30], "gc keeps the last `keep`"
        step, restored = mgr.restore(state)
        assert step == 30
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   np.arange(10.0) * 30)


def test_checkpoint_async_and_atomic():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=True)
        mgr.save(1, {"x": jnp.ones(4)})
        mgr.wait()
        assert mgr.latest_step() == 1
        assert not any(p.endswith(".tmp") for p in os.listdir(d))


def test_checkpoint_restore_missing_raises():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        with pytest.raises(FileNotFoundError):
            mgr.restore({"x": jnp.ones(2)})


# ----------------------------------------------------------- fault handling

def test_straggler_detection():
    hb = Heartbeat(4)
    now = now_s()   # Heartbeat stamps are monotonic — use the same clock
    for w in range(4):
        hb.beat(w, 1)
        hb.beat(w, 2)
    hb.step_times = [0.1] * 20
    hb.last_time[3] = now - 10.0       # worker 3 silent for 10s
    pol = StragglerPolicy(factor=3.0, min_deadline_s=0.5)
    assert pol.stragglers(hb, now=now) == [3]


def test_retry_loop_recovers_then_raises():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise FloatingPointError("NaN loss")
        return "ok"

    assert RetryLoop(max_retries=3, backoff_s=0.0).run(flaky) == "ok"

    def always_bad():
        raise RuntimeError("device lost")

    with pytest.raises(RuntimeError):
        RetryLoop(max_retries=1, backoff_s=0.0).run(always_bad)


def test_elastic_plan_covers_all_shards():
    plan = elastic_plan(16, [0, 2, 5])
    got = sorted(s for shards in plan.values() for s in shards)
    assert got == list(range(16))
    sizes = [len(v) for v in plan.values()]
    assert max(sizes) - min(sizes) <= 1


# ----------------------------------------------------------- data pipeline

def test_stream_resume_from_state():
    cfg = DataConfig(128, 8, 4, seed=5)
    s1 = SyntheticTokenStream(cfg)
    s1.next_batch()
    state = s1.state()
    want = s1.next_batch()
    s2 = SyntheticTokenStream.restore(cfg, state)
    got = s2.next_batch()
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_stream_labels_shifted():
    cfg = DataConfig(128, 8, 2, seed=1)
    b = SyntheticTokenStream(cfg).next_batch()
    assert b["tokens"].shape == (2, 8)
    assert b["labels"].shape == (2, 8)
    # labels are the next-token shift of the same underlying stream
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()


# ----------------------------------------------------------- optimizer

def test_adamw_converges_quadratic():
    cfg = optim_lib.OptConfig(lr=0.1, warmup_steps=1, decay_steps=200,
                              weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = optim_lib.init_opt_state(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}       # d/dw ||w||²
        params, opt, m = optim_lib.adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_adamw_int8_converges_like_f32():
    """int8-moment Adam reaches the same optimum as f32 Adam (trajectories
    may transiently diverge — Adam is sign-like early — but both must land
    on the quadratic's minimum w* = -0.2)."""
    rng = np.random.default_rng(0)
    w0 = jnp.asarray(rng.normal(0, 1, (4, 256)).astype(np.float32))
    finals = {}
    for name, dt in (("f32", "f32"), ("int8", "int8")):
        cfg = optim_lib.OptConfig(lr=0.05, warmup_steps=1, decay_steps=400,
                                  weight_decay=0.0, moments_dtype=dt)
        p = {"w": w0}
        o = optim_lib.init_opt_state(p, cfg)
        for _ in range(250):
            g = {"w": p["w"] * 0.5 + 0.1}     # minimum at w* = -0.2
            p, o, _ = optim_lib.adamw_update(g, o, p, cfg)
        finals[name] = p["w"]
    for name, w in finals.items():
        err = float(jnp.abs(w + 0.2).max())
        assert err < 0.05, f"{name} did not converge: {err}"


def test_grad_clipping():
    cfg = optim_lib.OptConfig(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    opt = optim_lib.init_opt_state(params, cfg)
    _, _, m = optim_lib.adamw_update({"w": jnp.full(3, 1e6)}, opt, params, cfg)
    assert float(m["grad_norm"]) > 1e5  # reported raw norm


# ----------------------------------------------------------- executor

def test_striping_preserves_prefix_semantics():
    tbl = table_lib.from_columns("s", synth.sessions_table(20_000, seed=8))
    fam = samp_lib.build_family(tbl, ("OS",), k1=500.0, m=3)
    for n_shards in (1, 3, 4):
        striped = exec_lib.stripe_family(fam, n_shards)
        for k in fam.ks:
            ftab = np.asarray(striped.freq_table)
            ek = np.asarray(striped.unit) * \
                ftab[np.asarray(striped.strat).astype(np.int64)]
            in_prefix = (ek < k) & np.asarray(striped.valid)
            assert in_prefix.sum() == fam.prefix_for_k(k)
            per_shard = in_prefix.sum(axis=1)
            assert per_shard.max() - per_shard.min() <= 1, \
                "prefix must stay balanced across shards"


def test_predicate_dnf_evaluation():
    from repro.core.types import Atom, CmpOp, Conjunction, Predicate
    cols = {"a": jnp.asarray([1, 2, 3, 4]), "b": jnp.asarray([10, 20, 30, 40])}
    pred = Predicate((
        Conjunction((Atom("a", CmpOp.LE, 2), Atom("b", CmpOp.GE, 20))),
        Conjunction((Atom("a", CmpOp.EQ, 4),)),
    ))
    bound = exec_lib.bind_predicate(pred, lambda c, v: float(v))
    mask = np.asarray(exec_lib.predicate_mask(cols, bound))
    np.testing.assert_array_equal(mask, [False, True, False, True])
