"""Fused memory-lean scan kernel: parity, narrow dtypes, one-pass quantiles.

Interpret-mode sweeps (the repo's substitute for hypothesis, which is not
installed: seeded parametrized cases) covering the in-kernel HT derivation
against ref.agg_scan_fused_ref, bit-identity with the pre-fusion batched
kernel, narrow dtype widths, the 127-atom template limit, padding rows
(entry_key=+inf), ghost slots, 1-stratum freq tables, the fused quantile
kernel, the grouped_quantile empty-selection guard, and the engine's
one-pass QUANTILE execution (observable through its program caches).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AggOp, Atom, BlinkDB, CmpOp, EngineConfig, Predicate,
                        Query)
from repro.core import executor as exec_lib
from repro.core import table as table_lib
from repro.data import synth
from repro.kernels import ref
from repro.kernels.agg_scan import (CONST_LANES, MAX_FUSED_STRATA,
                                    agg_scan_batched_pallas,
                                    agg_scan_fused_pallas,
                                    quantile_scan_pallas)


def _fused_case(rng, n, n_groups, q, n_atoms, n_strata=37,
                strat_dtype=np.int8, atom_dtype=np.int8, ghost_frac=0.0):
    values = jnp.asarray(rng.normal(5, 2, n).astype(np.float32))
    unit = jnp.asarray(rng.random(n).astype(np.float32))
    strat = jnp.asarray(rng.integers(0, n_strata, n).astype(strat_dtype))
    ftab = jnp.asarray(rng.integers(1, 500, n_strata).astype(np.float32))
    valid = jnp.asarray(rng.random(n) >= ghost_frac)
    codes = jnp.asarray(rng.integers(0, n_groups, n).astype(atom_dtype))
    atoms = tuple(jnp.asarray(rng.integers(0, 8, n).astype(atom_dtype))
                  for _ in range(n_atoms))
    ks = jnp.asarray(rng.uniform(20, 400, q).astype(np.float32))
    consts = jnp.asarray(rng.integers(0, 8, (q, n_atoms)).astype(np.float32))
    return values, unit, strat, ftab, valid, atoms, codes, ks, consts


def _assert_fused_parity(args, ops_struct, n_groups, atom_slots=None, **kw):
    got = agg_scan_fused_pallas(*args, ops_struct=ops_struct,
                                atom_slots=atom_slots, n_groups=n_groups,
                                interpret=True, **kw)
    want = ref.agg_scan_fused_ref(*args, ops_struct=ops_struct,
                                  atom_slots=atom_slots, n_groups=n_groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-3)
    return got


# ------------------------------------------------------------ kernel parity

@pytest.mark.parametrize("n", [1, 100, 5000])
@pytest.mark.parametrize("n_groups", [1, 600])
def test_fused_kernel_shapes(n, n_groups):
    rng = np.random.default_rng(n * 7 + n_groups)
    args = _fused_case(rng, n, n_groups, q=5, n_atoms=2)
    _assert_fused_parity(args, ((CmpOp.EQ,), (CmpOp.GT,)), n_groups)


@pytest.mark.parametrize("strat_dtype", [np.int8, np.int16, np.int32])
@pytest.mark.parametrize("atom_dtype", [np.int8, np.int16, np.int32])
def test_fused_kernel_narrow_dtype_widths(strat_dtype, atom_dtype):
    """Stored width must not change results: the kernel widens in VMEM."""
    rng = np.random.default_rng(11)
    args = _fused_case(rng, 4096, 12, q=4, n_atoms=2,
                       strat_dtype=strat_dtype, atom_dtype=atom_dtype)
    _assert_fused_parity(args, ((CmpOp.EQ, CmpOp.LE),), 12)


def test_fused_kernel_ghost_slots():
    """Tombstoned slots (valid=False) must contribute to no statistic."""
    rng = np.random.default_rng(12)
    args = _fused_case(rng, 3000, 8, q=3, n_atoms=1, ghost_frac=0.35)
    _assert_fused_parity(args, ((CmpOp.GT,),), 8)


def test_fused_kernel_all_ghosts_and_padding():
    """All-invalid input (every row a ghost) + implicit padding rows: the
    pad fill (unit=+inf) and valid=False must both zero the output."""
    rng = np.random.default_rng(13)
    v, u, s, ftab, _, atoms, c, ks, consts = _fused_case(
        rng, 1000, 4, q=2, n_atoms=1)
    dead = jnp.zeros(1000, bool)
    got = agg_scan_fused_pallas(v, u, s, ftab, dead, atoms, c, ks, consts,
                                ops_struct=((CmpOp.GT,),), n_groups=4,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_fused_kernel_one_stratum():
    """Degenerate 1-entry freq table (single stratum, strat all zero)."""
    rng = np.random.default_rng(14)
    args = _fused_case(rng, 2500, 6, q=3, n_atoms=1, n_strata=1)
    _assert_fused_parity(args, ((CmpOp.EQ,),), 6)


def test_fused_kernel_multichunk_freq_table():
    """freq table wider than one 128-lane chunk (statically unrolled)."""
    rng = np.random.default_rng(15)
    args = _fused_case(rng, 4000, 10, q=3, n_atoms=1, n_strata=300,
                       strat_dtype=np.int16)
    _assert_fused_parity(args, ((CmpOp.LE,),), 10)


def test_fused_kernel_no_predicate():
    rng = np.random.default_rng(16)
    v, u, s, ftab, va, _, c, ks, _ = _fused_case(rng, 3000, 5, q=3, n_atoms=0)
    args = (v, u, s, ftab, va, (), c, ks, jnp.zeros((3, 0), jnp.float32))
    _assert_fused_parity(args, (), 5)


def test_fused_kernel_atom_slot_dedup():
    """Two template atoms on ONE streamed column block (slot sharing)."""
    rng = np.random.default_rng(17)
    v, u, s, ftab, va, atoms, c, ks, _ = _fused_case(rng, 2000, 4, q=2,
                                                     n_atoms=1)
    consts = jnp.asarray(rng.integers(0, 8, (2, 2)).astype(np.float32))
    args = (v, u, s, ftab, va, atoms, c, ks, consts)
    _assert_fused_parity(args, ((CmpOp.GE, CmpOp.LE),), 4,
                         atom_slots=(0, 0))


def test_fused_kernel_127_atom_limit():
    """The qconst layout admits exactly CONST_LANES-1 = 127 atoms."""
    rng = np.random.default_rng(18)
    n, n_atoms = 512, CONST_LANES - 1
    v, u, s, ftab, va, atoms, c, ks, _ = _fused_case(rng, n, 2, q=1,
                                                     n_atoms=1)
    consts = jnp.asarray(rng.integers(0, 8, (1, n_atoms)).astype(np.float32))
    struct = ((CmpOp.GE,) * n_atoms,)
    args = (v, u, s, ftab, va, atoms, c, ks, consts)
    _assert_fused_parity(args, struct, 2, atom_slots=(0,) * n_atoms)
    # one more atom must be rejected, not silently mis-addressed
    with pytest.raises(ValueError, match="atoms"):
        agg_scan_fused_pallas(v, u, s, ftab, va, atoms, c, ks,
                              jnp.zeros((1, n_atoms + 1), jnp.float32),
                              ops_struct=((CmpOp.GE,) * (n_atoms + 1),),
                              atom_slots=(0,) * (n_atoms + 1), n_groups=2,
                              interpret=True)


def test_fused_kernel_strata_cap():
    rng = np.random.default_rng(19)
    v, u, s, ftab, va, atoms, c, ks, consts = _fused_case(rng, 256, 2, 1, 1)
    big = jnp.ones(MAX_FUSED_STRATA + 1, jnp.float32)
    with pytest.raises(ValueError, match="strata"):
        agg_scan_fused_pallas(v, u, s, big, va, atoms, c, ks, consts,
                              ops_struct=((CmpOp.EQ,),), n_groups=2,
                              interpret=True)


def test_fused_bit_identical_to_prefusion_kernel():
    """Acceptance: given the SAME derived freq/entry_key the fused kernel's
    accumulation is bit-for-bit the pre-fusion batched kernel's — in-kernel
    derivation changes where freq/entry_key are computed, not a single bit
    of the reduction."""
    rng = np.random.default_rng(20)
    v, u, s, ftab, va, atoms, c, ks, consts = _fused_case(
        rng, 6000, 24, q=4, n_atoms=2, n_strata=200, strat_dtype=np.int16,
        ghost_frac=0.1)
    struct = ((CmpOp.EQ,), (CmpOp.GT,))
    fused = agg_scan_fused_pallas(v, u, s, ftab, va, atoms, c, ks, consts,
                                  ops_struct=struct, n_groups=24,
                                  interpret=True)
    freq = ftab[s.astype(jnp.int32)]
    ek = jnp.where(va, u * freq, jnp.inf)
    old_atoms = jnp.stack([a.astype(jnp.float32) for a in atoms])
    old = agg_scan_batched_pallas(v, freq, ek, old_atoms, c.astype(jnp.int32),
                                  ks, consts, ops_struct=struct, n_groups=24,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(old))


# -------------------------------------------------------- quantile kernel

def test_quantile_kernel_moments_match_fused_scan():
    """The one-pass quantile kernel's moment half is bit-identical to the
    fused scan at the same k (same blocks, same accumulation order)."""
    rng = np.random.default_rng(30)
    v, u, s, ftab, va, atoms, c, ks, consts = _fused_case(
        rng, 5000, 10, q=1, n_atoms=1, ghost_frac=0.2)
    struct = ((CmpOp.LE,),)
    lo, hi = float(np.asarray(v).min()), float(np.asarray(v).max())
    mom, hist = quantile_scan_pallas(
        v, u, s, ftab, va, atoms, c, ks[0], jnp.float32(lo), jnp.float32(hi),
        consts[0], ops_struct=struct, n_groups=10, interpret=True)
    fused = agg_scan_fused_pallas(v, u, s, ftab, va, atoms, c, ks, consts,
                                  ops_struct=struct, n_groups=10,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(mom), np.asarray(fused[0]))
    assert hist.shape == (256, 10)


@pytest.mark.parametrize("case_seed", [0, 1, 2])
def test_quantile_kernel_histogram_matches_ref(case_seed):
    rng = np.random.default_rng(40 + case_seed)
    n, n_groups = 4000, 7
    v, u, s, ftab, va, atoms, c, ks, consts = _fused_case(
        rng, n, n_groups, q=1, n_atoms=1, ghost_frac=0.1)
    struct = ((CmpOp.LE,),)
    lo, hi = float(np.asarray(v).min()), float(np.asarray(v).max())
    mom, hist = quantile_scan_pallas(
        v, u, s, ftab, va, atoms, c, ks[0], jnp.float32(lo), jnp.float32(hi),
        consts[0], ops_struct=struct, n_groups=n_groups, interpret=True)
    # oracle weights: HT rates over the surviving prefix
    freq = ftab[s.astype(jnp.int32)]
    ek = jnp.where(va, u * freq, jnp.inf)
    pred = atoms[0].astype(jnp.float32) <= consts[0, 0]
    mask = (ek < ks[0]) & pred
    w = mask / jnp.minimum(1.0, ks[0] / freq)
    want = ref.quantile_hist_ref(v, w, c.astype(jnp.int32), n_groups,
                                 lo, hi, 256)
    np.testing.assert_allclose(np.asarray(hist), np.asarray(want).T,
                               rtol=2e-5, atol=1e-3)
    # histogram mass == weighted selection mass (nothing lost to clipping)
    np.testing.assert_allclose(np.asarray(hist).sum(),
                               float(jnp.sum(w)), rtol=2e-5, atol=1e-2)


def test_quantile_kernel_bins_must_be_lane_aligned():
    rng = np.random.default_rng(50)
    v, u, s, ftab, va, atoms, c, ks, consts = _fused_case(rng, 256, 2, 1, 1)
    with pytest.raises(ValueError, match="128"):
        quantile_scan_pallas(v, u, s, ftab, va, atoms, c, ks[0],
                             jnp.float32(0), jnp.float32(1), consts[0],
                             ops_struct=((CmpOp.LE,),), n_groups=2,
                             n_bins=100, interpret=True)


# --------------------------------------- grouped_quantile empty-selection

def test_grouped_quantile_empty_selection_is_defined():
    """Regression: with NO selected row, lo/hi were ±inf (NaN bin indices)
    and all-masked groups divided by the clamped total. Must be (0, 0)."""
    v = jnp.asarray(np.linspace(0, 10, 64, dtype=np.float32))
    w = jnp.zeros(64, jnp.float32)
    g = jnp.asarray(np.arange(64) % 4, dtype=jnp.int32)
    qv, dens = exec_lib.grouped_quantile(v, w, g, 4, 0.5)
    np.testing.assert_array_equal(np.asarray(qv), 0.0)
    np.testing.assert_array_equal(np.asarray(dens), 0.0)
    assert np.all(np.isfinite(np.asarray(qv)))


def test_grouped_quantile_one_empty_group():
    """A single all-masked group yields (0, 0) without disturbing others."""
    rng = np.random.default_rng(60)
    v = jnp.asarray(rng.normal(5, 2, 512).astype(np.float32))
    g = jnp.asarray((np.arange(512) % 3).astype(np.int32))
    w = jnp.asarray((np.asarray(g) != 1).astype(np.float32))
    qv, dens = exec_lib.grouped_quantile(v, w, g, 3, 0.5)
    qv, dens = np.asarray(qv), np.asarray(dens)
    assert qv[1] == 0.0 and dens[1] == 0.0
    for gi in (0, 2):
        sel = np.sort(np.asarray(v)[np.asarray(g) == gi])
        assert abs(qv[gi] - np.median(sel)) < (sel.max() - sel.min()) / 16


def test_hist_to_quantile_empty_guard():
    hist = jnp.zeros((3, 256), jnp.float32)
    qv, dens = exec_lib.hist_to_quantile(hist, 0.0, 1.0, 0.5)
    np.testing.assert_array_equal(np.asarray(qv), 0.0)
    np.testing.assert_array_equal(np.asarray(dens), 0.0)


# ----------------------------------------------- engine: one-pass QUANTILE

@pytest.mark.parametrize("use_pallas", [False, True])
def test_engine_quantile_runs_one_pass(use_pallas):
    """A QUANTILE query on the clean path compiles ONLY the fused one-pass
    program: the plain scan cache stays empty for its template (the old
    engine ran a moments scan + a second full-column quantile pass)."""
    tbl = table_lib.from_columns("s", synth.sessions_table(15_000, seed=3))
    db = BlinkDB(EngineConfig(k1=400.0, m=3, seed=1, use_pallas=use_pallas))
    db.register_table("s", tbl)
    db.add_family("s", ("City",))
    q = Query("s", AggOp.QUANTILE, value_column="SessionTime", quantile=0.5,
              predicate=Predicate.where(
                  Atom("City", CmpOp.EQ, tbl.dictionaries["City"][0])))
    ans = db.query(q)
    assert ans.groups and np.isfinite(ans.groups[0].estimate)
    assert db._quantile_programs, "fused quantile program not compiled"
    assert not db._programs, \
        "QUANTILE compiled a separate moments scan (second pass)"


def test_engine_quantile_pallas_matches_jnp_roughly():
    """Histogram binning differs (family-global vs selection-local range) so
    the two paths agree to bin resolution, not bitwise."""
    tbl = table_lib.from_columns("s", synth.sessions_table(15_000, seed=3))
    answers = {}
    for up in (False, True):
        db = BlinkDB(EngineConfig(k1=400.0, m=3, seed=1, use_pallas=up))
        db.register_table("s", tbl)
        db.add_family("s", ())
        q = Query("s", AggOp.QUANTILE, value_column="SessionTime",
                  quantile=0.5)
        answers[up] = db.query(q).groups[0].estimate
    col = np.asarray(tbl.columns["SessionTime"])
    span = float(col.max() - col.min())
    assert abs(answers[True] - answers[False]) <= span / 64
