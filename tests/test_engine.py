"""End-to-end engine behaviour: the paper's §2 example queries with error and
time bounds, family selection, disjunction rewrite, quantiles, exact path."""
import numpy as np
import pytest

from repro.core import (AggOp, Atom, BlinkDB, CmpOp, Conjunction, EngineConfig,
                        ErrorBound, Predicate, Query, QueryTemplate, TimeBound)
from repro.core import table as table_lib
from repro.data import synth


@pytest.fixture(scope="module")
def db():
    tbl = table_lib.from_columns("sessions", synth.sessions_table(120_000, seed=5))
    db = BlinkDB(EngineConfig(k1=2000.0, c=2.0, m=4, uniform_fraction=0.3))
    db.register_table("sessions", tbl)
    templates = [
        QueryTemplate(frozenset({"City"}), 0.30),
        QueryTemplate(frozenset({"Genre", "City"}), 0.25),
        QueryTemplate(frozenset({"OS", "URL"}), 0.25),
        QueryTemplate(frozenset({"Genre"}), 0.20),
    ]
    db.build_samples("sessions", templates, storage_budget_fraction=0.5)
    return db


def test_samples_built_within_budget(db):
    tbl = db.tables["sessions"]
    fams = db.families["sessions"]
    assert () in fams, "uniform family always present"
    strat = {p: f for p, f in fams.items() if p}
    assert strat, "optimizer must choose at least one stratified family"
    total = sum(f.storage_bytes(tbl.row_bytes()) for f in strat.values())
    assert total <= 0.5 * tbl.nbytes * 1.05


def test_paper_example_count_groupby_error_bound(db):
    """§2: SELECT COUNT(*) WHERE Genre='g03' GROUP BY OS ERROR WITHIN 10%."""
    q = Query("sessions", AggOp.COUNT,
              predicate=Predicate.where(Atom("Genre", CmpOp.EQ, "g03")),
              group_by=("OS",), bound=ErrorBound(0.10, 0.95))
    ans = db.query(q)
    exact = db.exact_query(q)
    exact_by_key = {g.key: g.estimate for g in exact.groups}
    assert len(ans.groups) >= len(exact_by_key) - 1  # subgroup coverage
    for g in ans.groups:
        truth = exact_by_key.get(g.key)
        if truth is None or g.exact:
            continue
        rel = abs(g.estimate - truth) / truth
        assert rel < 0.25, f"{g.key}: rel err {rel:.3f}"
        # CI sanity: stderr positive, CI ordered
        assert g.ci_low <= g.estimate <= g.ci_high
    assert ans.rows_read < db.tables["sessions"].n_rows


def test_avg_with_error_bound_meets_bound(db):
    q = Query("sessions", AggOp.AVG, value_column="SessionTime",
              group_by=("OS",), bound=ErrorBound(0.05, 0.95))
    ans = db.query(q)
    exact = {g.key: g.estimate for g in db.exact_query(q).groups}
    hit = 0
    for g in ans.groups:
        truth = exact[g.key]
        if abs(g.estimate - truth) / truth <= 0.05:
            hit += 1
    assert hit >= len(ans.groups) - 1, "95% of groups within the 5% bound"


def test_time_bound_reads_fewer_rows(db):
    q_fast = Query("sessions", AggOp.AVG, value_column="SessionTime",
                   group_by=("City",), bound=TimeBound(0.003))
    q_slow = Query("sessions", AggOp.AVG, value_column="SessionTime",
                   group_by=("City",), bound=TimeBound(10.0))
    a_fast = db.query(q_fast)
    a_slow = db.query(q_slow)
    assert a_fast.rows_read <= a_slow.rows_read


def test_family_selection_superset_rule(db):
    """Query on City must use a stratified family whose φ ⊇ {City} if the
    optimizer built one; otherwise whatever probing chose is recorded."""
    q = Query("sessions", AggOp.COUNT,
              predicate=Predicate.where(Atom("City", CmpOp.EQ, "city000")),
              bound=ErrorBound(0.1))
    ans = db.query(q)
    fams = db.families["sessions"]
    supersets = [p for p in fams if p and "City" in p]
    if supersets:
        assert "City" in ans.sample_phi


def test_rare_group_present_with_stratified_absent_in_uniform(db):
    """Paper's core claim: stratified samples avoid missing subgroups."""
    tbl = db.tables["sessions"]
    city_codes = np.asarray(tbl.columns["City"])
    counts = np.bincount(city_codes, minlength=tbl.cardinality("City"))
    rare_code = int(np.argsort(counts)[np.searchsorted(np.sort(counts), 1, side="left")])
    # pick the rarest city that still exists
    present = np.nonzero(counts > 0)[0]
    rare_code = int(present[np.argmin(counts[present])])
    rare_city = tbl.decode_value("City", rare_code)
    q = Query("sessions", AggOp.COUNT,
              predicate=Predicate.where(Atom("City", CmpOp.EQ, rare_city)),
              bound=ErrorBound(0.2))
    ans = db.query(q)
    assert ans.groups and ans.groups[0].estimate > 0
    if "City" in ans.sample_phi and counts[rare_code] <= db.config.k1:
        # stratum under the cap → contained entirely → exact
        assert abs(ans.groups[0].estimate - counts[rare_code]) < 1e-3


def test_disjunctive_predicate_union(db):
    pred = Predicate((
        Conjunction((Atom("OS", CmpOp.EQ, "os0"),)),
        Conjunction((Atom("OS", CmpOp.EQ, "os1"),)),
    ))
    q = Query("sessions", AggOp.COUNT, predicate=pred, bound=ErrorBound(0.1))
    ans = db.query(q)
    exact = db.exact_query(q)
    # exact path evaluates the DNF directly (disjoint disjuncts here)
    truth = sum(g.estimate for g in exact.groups)
    got = sum(g.estimate for g in ans.groups)
    assert abs(got - truth) / truth < 0.15


def test_quantile_estimate(db):
    q = Query("sessions", AggOp.QUANTILE, value_column="SessionTime",
              quantile=0.5, bound=ErrorBound(0.10, 0.95))
    ans = db.query(q)
    exact = db.exact_query(q)
    truth = exact.groups[0].estimate
    got = ans.groups[0].estimate
    assert abs(got - truth) / truth < 0.12
    assert ans.groups[0].stderr > 0


def test_sum_unbiased_across_seeds():
    """Rebuild samples with different seeds: SUM estimates scatter around the
    truth (offline-sampling §2.1 story)."""
    tbl = table_lib.from_columns("s", synth.sessions_table(40_000, seed=9))
    q = Query("s", AggOp.SUM, value_column="SessionTime",
              predicate=Predicate.where(Atom("OS", CmpOp.EQ, "os1")))
    ests = []
    truth = None
    for seed in range(6):
        db = BlinkDB(EngineConfig(k1=800.0, c=2.0, m=3, seed=seed))
        db.register_table("s", tbl)
        db.add_family("s", ("OS",))
        db.add_family("s", ())
        ans = db.query(Query(**{**q.__dict__, "bound": ErrorBound(0.05)}))
        ests.append(sum(g.estimate for g in ans.groups))
        if truth is None:
            truth = sum(g.estimate for g in db.exact_query(q).groups)
    rel = abs(np.mean(ests) - truth) / truth
    assert rel < 0.03, f"mean over seeds deviates {rel:.3f}"
