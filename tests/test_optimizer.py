"""§3.2 optimization framework: budget feasibility, greedy vs exact quality,
Eq.-5 change budget, candidate enumeration."""
import itertools

import numpy as np
import pytest

from repro.core import optimizer as opt
from repro.core.types import QueryTemplate


def _mk(phi, storage, nd, delta):
    return opt.Candidate(frozenset(phi), storage, nd, delta)


def _wl(*items):
    ts, ds, nds = [], [], []
    for cols, w, delta, nd in items:
        ts.append(QueryTemplate(frozenset(cols), w))
        ds.append(delta)
        nds.append(nd)
    return opt.Workload(tuple(ts), tuple(ds), tuple(nds))


def test_enumerate_candidates_subsets_only():
    templates = [QueryTemplate(frozenset({"a", "b", "c"}), 0.6),
                 QueryTemplate(frozenset({"c", "d"}), 0.4)]
    cands = opt.enumerate_candidates(
        templates, lambda phi: (10.0, 5.0, 2.0), max_cols=2)
    phis = {tuple(sorted(c.phi)) for c in cands}
    assert ("a",) in phis and ("a", "b") in phis and ("c", "d") in phis
    assert ("a", "b", "c") not in phis, "max_cols=2 respected"
    assert ("a", "d") not in phis, "never co-occurred in a template"


def test_budget_respected_and_coverage():
    cands = [_mk({"city"}, 40, 100, 80), _mk({"os"}, 25, 5, 1),
             _mk({"url"}, 60, 400, 300), _mk({"city", "os"}, 70, 450, 350)]
    wl = _wl(({"city"}, 0.4, 80, 100), ({"city", "os"}, 0.4, 350, 450),
             ({"url"}, 0.2, 300, 400))
    sol = opt.solve_greedy(cands, wl, budget=80.0)
    assert sol.storage_used <= 80.0
    assert all(0 <= y <= 1 for y in sol.coverage.values())


@pytest.mark.parametrize("seed", range(8))
def test_greedy_matches_exact_on_small_instances(seed):
    """Greedy+swap must reach ≥95% of the exact optimum (usually 100%)."""
    rng = np.random.default_rng(seed)
    cols = ["a", "b", "c", "d", "e"]
    cands = []
    for r in (1, 2):
        for combo in itertools.combinations(cols, r):
            cands.append(_mk(set(combo), float(rng.integers(10, 80)),
                             float(rng.integers(4, 300)),
                             float(rng.integers(1, 200))))
    wl_items = []
    for _ in range(4):
        k = int(rng.integers(1, 3))
        sel = rng.choice(len(cols), size=k, replace=False)
        colset = {cols[i] for i in sel}
        wl_items.append((colset, float(rng.random() + 0.1),
                         float(rng.integers(10, 200)), float(rng.integers(10, 400))))
    wl = _wl(*wl_items)
    budget = 120.0
    g = opt.solve_greedy(cands, wl, budget)
    e = opt.solve_exact(cands, wl, budget)
    assert g.storage_used <= budget and e.storage_used <= budget
    assert g.objective >= 0.95 * e.objective - 1e-9, (g.objective, e.objective)


def test_change_budget_eq5():
    """r=0 freezes the existing set; r=1 allows full churn (§3.2.3)."""
    cands = [_mk({"a"}, 50, 10, 5), _mk({"b"}, 50, 200, 150)]
    wl = _wl(({"b"}, 1.0, 150, 200))
    existing = frozenset({frozenset({"a"})})
    frozen = opt.solve_greedy(cands, wl, budget=100.0, existing=existing,
                              change_fraction=0.0)
    assert {tuple(sorted(c.phi)) for c in frozen.chosen} == {("a",)}, \
        "r=0: no creations or deletions allowed"
    free = opt.solve_greedy(cands, wl, budget=100.0, existing=existing,
                            change_fraction=1.0)
    assert frozenset({"b"}) in {c.phi for c in free.chosen}, \
        "r=1: optimizer free to adopt the better family"
    ex = opt.solve_exact(cands, wl, budget=100.0, existing=existing,
                         change_fraction=0.0)
    assert {tuple(sorted(c.phi)) for c in ex.chosen} == {("a",)}


def test_skew_drives_selection():
    """Higher Δ(φ) (more skew) wins at equal cost/weight (§3.2.1)."""
    cands = [_mk({"uniformcol"}, 50, 100, 0.0), _mk({"skewcol"}, 50, 100, 500.0)]
    wl = _wl(({"uniformcol"}, 0.5, 0.0, 100), ({"skewcol"}, 0.5, 500.0, 100))
    sol = opt.solve_greedy(cands, wl, budget=50.0)
    assert {c.phi for c in sol.chosen} == {frozenset({"skewcol"})}
