"""Property-based tests (hypothesis) on the system's statistical invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip (not error) without it
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

from repro.core import estimators as est_lib
from repro.core import sampling as samp_lib
from repro.core import table as table_lib
from repro.core.optimizer import Candidate, Workload, solve_greedy
from repro.core.types import (AggOp, Atom, CmpOp, Conjunction, ErrorBound,
                              Predicate, Query, QueryTemplate, TimeBound)
from repro.train import optim as optim_lib


@st.composite
def small_table(draw):
    n = draw(st.integers(200, 2000))
    card = draw(st.integers(2, 30))
    skew = draw(st.floats(0.0, 2.0))
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    p = np.arange(1, card + 1, dtype=np.float64) ** -(skew + 0.01)
    p /= p.sum()
    key = rng.choice(card, size=n, p=p).astype(np.int32)
    x = rng.gamma(2.0, 3.0, n).astype(np.float32)
    return table_lib.from_columns(
        "t", {"key": key.astype(str), "x": x}), seed


@settings(max_examples=25, deadline=None)
@given(small_table(), st.floats(5.0, 200.0))
def test_family_invariants(tbl_seed, k1):
    """For ANY table and cap: nesting holds, rates are valid probabilities,
    expected-rows formula matches the histogram."""
    tbl, seed = tbl_seed
    fam = samp_lib.build_family(tbl, ("key",), k1=k1, c=2.0, m=3, seed=seed)
    ek = np.asarray(fam.entry_key)
    assert np.all(np.diff(ek) >= 0)
    assert fam.prefix_sizes[0] == fam.n_rows
    assert list(fam.prefix_sizes) == sorted(fam.prefix_sizes, reverse=True)
    for k in fam.ks:
        r = np.asarray(fam.rate(k))
        assert np.all((r > 0) & (r <= 1.0))
    expect = samp_lib.expected_sample_rows(fam.stratum_freqs, k1)
    assert fam.n_rows <= tbl.n_rows
    assert abs(fam.n_rows - expect) <= 6 * np.sqrt(expect) + 2


@settings(max_examples=20, deadline=None)
@given(small_table())
def test_ht_estimates_bounded_and_exact_when_full(tbl_seed):
    """HT estimate of a total never exceeds what rates allow, and equals the
    exact total when every stratum is below the cap (rate=1 everywhere)."""
    tbl, seed = tbl_seed
    big_k = float(tbl.n_rows + 1)
    fam = samp_lib.build_family(tbl, ("key",), k1=big_k, m=1, seed=seed)
    mom = est_lib.grouped_moments(
        fam.columns["x"], fam.rate(big_k),
        jnp.ones(fam.n_rows, bool),
        jnp.zeros(fam.n_rows, jnp.int32), 1)
    est = est_lib.estimate(AggOp.SUM, mom)
    truth = float(np.asarray(tbl.columns["x"]).sum())
    np.testing.assert_allclose(float(est.value[0]), truth, rtol=1e-3)
    np.testing.assert_allclose(float(est.variance[0]), 0.0, atol=truth * 1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.floats(0.05, 0.95))
def test_count_variance_decreases_with_rate(seed, p_low):
    """Higher sampling rate => lower estimated variance (same data)."""
    rng = np.random.default_rng(seed)
    n = 3000
    x = jnp.ones(n)
    g = jnp.zeros(n, jnp.int32)
    p_high = min(p_low * 2, 1.0)
    vs = []
    for p in (p_low, p_high):
        mask = jnp.asarray(rng.random(n) < p)
        rates = jnp.full((n,), p, jnp.float32)
        mom = est_lib.grouped_moments(x, rates, mask, g, 1)
        vs.append(float(est_lib.estimate(AggOp.COUNT, mom).variance[0]))
    assert vs[1] <= vs[0] * 1.5 + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.floats(1, 100), st.floats(1, 300),
                          st.floats(0, 200)), min_size=2, max_size=10),
       st.floats(50, 500))
def test_optimizer_never_exceeds_budget(items, budget):
    """Greedy solution is always budget-feasible and objective-monotone in
    the budget."""
    cands = [Candidate(frozenset({f"c{i}"}), s, nd, dl)
             for i, (s, nd, dl) in enumerate(items)]
    wl = Workload(
        tuple(QueryTemplate(frozenset({f"c{i}"}), 1.0 / len(items))
              for i in range(len(items))),
        tuple(dl for _, _, dl in items),
        tuple(nd for _, nd, _ in items))
    sol1 = solve_greedy(cands, wl, budget)
    sol2 = solve_greedy(cands, wl, budget * 2)
    assert sol1.storage_used <= budget + 1e-6
    assert sol2.storage_used <= 2 * budget + 1e-6
    assert sol2.objective >= sol1.objective - 1e-9


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(2, 300))
def test_int8_moment_roundtrip(seed, nd, last):
    """Block-quantized moments reconstruct within absmax/127 per block."""
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(1, 5, nd - 1)) + (last,)
    x = jnp.asarray(rng.normal(0, 0.1, shape).astype(np.float32))
    q, s = optim_lib.quantize_i8(x, 128)
    back = optim_lib.dequantize_i8(q, s, 128)
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.asarray(jnp.max(jnp.abs(x))) / 127.0 + 1e-7
    assert err.max() <= bound * 1.01


@st.composite
def queries(draw):
    """Random DNF queries with numpy/python-mixed literal types."""
    def atom(_):
        col = draw(st.sampled_from(["a", "b", "c", "d"]))
        op = draw(st.sampled_from(list(CmpOp)))
        val = draw(st.one_of(
            st.floats(-10, 10, allow_nan=False).map(np.float32),
            st.floats(-10, 10, allow_nan=False),
            st.integers(-5, 5),
            st.sampled_from(["x", "y"]).map(np.str_)))
        return Atom(col, op, val)
    conjs = tuple(
        Conjunction(tuple(atom(None)
                          for _ in range(draw(st.integers(0, 3)))))
        for _ in range(draw(st.integers(1, 3))))
    bound = draw(st.one_of(
        st.none(),
        st.floats(0.01, 0.5, allow_nan=False).map(
            lambda e: ErrorBound(e, 0.95)),
        st.floats(0.1, 5.0, allow_nan=False).map(TimeBound)))
    return Query("t", draw(st.sampled_from([AggOp.COUNT, AggOp.SUM])),
                 draw(st.sampled_from([None, "v"])),
                 Predicate(conjs),
                 group_by=draw(st.sampled_from([(), ("g",)])),
                 bound=bound)


@settings(max_examples=60, deadline=None)
@given(queries(), st.randoms())
def test_query_normalization_idempotent_and_permutation_invariant(q, rnd):
    """normalized() is idempotent, hashable, and invariant under shuffling
    conjunct/atom order — the invariant the service answer cache and QCS
    stats rely on to not split entries on syntactic permutations."""
    n1 = q.normalized()
    assert n1.normalized() == n1              # idempotent
    assert hash(n1.normalized()) == hash(n1)
    shuffled_conjs = []
    for conj in q.predicate.disjuncts:
        atoms = list(conj.atoms)
        rnd.shuffle(atoms)
        shuffled_conjs.append(Conjunction(tuple(atoms)))
    rnd.shuffle(shuffled_conjs)
    q_perm = Query(q.table, q.agg, q.value_column,
                   Predicate(tuple(shuffled_conjs)), q.group_by,
                   q.quantile, q.bound)
    n2 = q_perm.normalized()
    assert n1 == n2 and hash(n1) == hash(n2)
    # the semantic template is preserved
    assert n1.where_group_columns == q.where_group_columns
    assert n1.table == q.table and n1.agg is q.agg


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_data_stream_deterministic_and_elastic(seed):
    """Any (shard, n_shards) slicing reproduces the same global batch."""
    from repro.data.tokens import DataConfig, SyntheticTokenStream
    cfg = DataConfig(vocab_size=256, seq_len=16, global_batch=8, seed=seed)
    full = SyntheticTokenStream(cfg, 0, 1).next_batch()
    parts = [SyntheticTokenStream(cfg, i, 4).next_batch() for i in range(4)]
    merged = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(full["tokens"], merged)
