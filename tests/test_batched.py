"""Batched shared-scan execution: kernel parity + engine equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AggOp, Atom, BlinkDB, CmpOp, EngineConfig, ErrorBound,
                        Conjunction, Predicate, Query)
from repro.core import executor as exec_lib
from repro.core import sampling as samp_lib
from repro.core import table as table_lib
from repro.data import synth
from repro.kernels import ref
from repro.kernels.agg_scan import agg_scan_batched_pallas


def _family_case(rng, n, n_groups, q, n_atoms):
    values = jnp.asarray(rng.normal(5, 2, n).astype(np.float32))
    freq = jnp.asarray(rng.integers(1, 500, n).astype(np.float32))
    entry_key = jnp.asarray((rng.random(n) * np.asarray(freq)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, n_groups, n).astype(np.int32))
    atoms = jnp.asarray(rng.integers(0, 8, (n_atoms, n)).astype(np.float32))
    ks = jnp.asarray(rng.uniform(20, 400, q).astype(np.float32))
    consts = jnp.asarray(rng.integers(0, 8, (q, n_atoms)).astype(np.float32))
    return values, freq, entry_key, atoms, codes, ks, consts


def _assert_parity(args, ops_struct, n_groups, **kw):
    values, freq, entry_key, atoms, codes, ks, consts = args
    got = agg_scan_batched_pallas(values, freq, entry_key, atoms, codes,
                                  ks, consts, ops_struct=ops_struct,
                                  n_groups=n_groups, interpret=True, **kw)
    want = ref.agg_scan_batched_ref(values, freq, entry_key, atoms, codes,
                                    ks, consts, ops_struct, n_groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=1e-3)


# ------------------------------------------------- kernel parity, odd shapes

@pytest.mark.parametrize("n", [1, 100, 5000])      # n not multiple of blocks
@pytest.mark.parametrize("n_groups", [1, 600])     # n_groups > block_groups
def test_batched_kernel_shapes(n, n_groups):
    rng = np.random.default_rng(n * 7 + n_groups)
    args = _family_case(rng, n, n_groups, q=5, n_atoms=2)
    _assert_parity(args, ((CmpOp.EQ,), (CmpOp.GT,)), n_groups)


def test_batched_kernel_q1():
    rng = np.random.default_rng(1)
    args = _family_case(rng, 3000, 16, q=1, n_atoms=1)
    _assert_parity(args, ((CmpOp.LE,),), 16)


def test_batched_kernel_empty_predicate():
    rng = np.random.default_rng(2)
    values, freq, ek, _, codes, ks, _ = _family_case(rng, 4000, 10, 3, 1)
    args = (values, freq, ek, jnp.zeros((0, 4000), jnp.float32), codes, ks,
            jnp.zeros((3, 0), jnp.float32))
    _assert_parity(args, (), 10)
    # single empty conjunction (Predicate.true() template) == no predicate
    args2 = (values, freq, ek, jnp.zeros((0, 4000), jnp.float32), codes, ks,
             jnp.zeros((3, 0), jnp.float32))
    _assert_parity(args2, ((),), 10)


def test_batched_kernel_all_masked():
    """Predicates that never match and prefixes that exclude every row."""
    rng = np.random.default_rng(3)
    values, freq, ek, atoms, codes, ks, _ = _family_case(rng, 2048, 8, 4, 1)
    consts = jnp.full((4, 1), 99.0, jnp.float32)     # atom values are < 8
    args = (values, freq, ek, atoms, codes, ks, consts)
    _assert_parity(args, ((CmpOp.EQ,),), 8)
    # prefix excludes everything: k below the smallest entry_key (but > 0)
    tiny = jnp.full((4,), float(np.asarray(ek).min()) * 0.5 + 1e-6, jnp.float32)
    got = agg_scan_batched_pallas(values, freq, ek, atoms, codes, tiny, consts,
                                  ops_struct=((CmpOp.EQ,),), n_groups=8,
                                  interpret=True)
    assert np.all(np.isfinite(np.asarray(got)))
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_batched_kernel_conjunction_mix():
    rng = np.random.default_rng(4)
    args = _family_case(rng, 5000, 37, q=7, n_atoms=3)
    _assert_parity(args, ((CmpOp.EQ, CmpOp.LE), (CmpOp.GT,)), 37,
                   block_rows=1024, block_groups=128)


# -------------------------------------------- executor: batched == sequential

@pytest.mark.parametrize("use_pallas", [False, True])
def test_make_batched_query_fn_matches_sequential(use_pallas):
    tbl = table_lib.from_columns("s", synth.sessions_table(20_000, seed=2))
    fam = samp_lib.build_family(tbl, ("City",), 400.0, m=3, seed=1)
    striped = exec_lib.stripe_family(fam, 1)
    struct = ((("City", CmpOp.EQ),),)
    n_groups = tbl.cardinality("OS")
    bfn = exec_lib.make_batched_query_fn(struct, "SessionTime", "OS",
                                         n_groups, use_pallas=use_pallas)
    sfn = exec_lib.make_query_fn(struct, "SessionTime", "OS",
                                 n_groups, use_pallas=use_pallas)
    args = exec_lib.scan_args(striped)
    ks = jnp.asarray([400.0, 200.0, 100.0], jnp.float32)
    consts = jnp.asarray([[0.0], [1.0], [2.0]], jnp.float32)
    mom = bfn(ks, consts, *args)
    for i in range(3):
        want = sfn(ks[i], ((float(consts[i, 0]),),), *args)
        for a, b in zip(jax.tree.leaves(mom), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(a[i]), np.asarray(b),
                                       rtol=1e-5, atol=1e-3)


# ------------------------------------------------- engine: query_batch

def _db(tbl, use_pallas=False):
    db = BlinkDB(EngineConfig(k1=500.0, m=3, seed=1, use_pallas=use_pallas))
    db.register_table("s", tbl)
    db.add_family("s", ("City",))
    db.add_family("s", ("OS",))
    db.add_family("s", ())
    return db


def _assert_answers_match(a, b):
    assert a.sample_phi == b.sample_phi
    assert a.sample_k == b.sample_k
    ka = {g.key: g for g in a.groups}
    kb = {g.key: g for g in b.groups}
    assert ka.keys() == kb.keys()
    for key in ka:
        np.testing.assert_allclose(ka[key].estimate, kb[key].estimate,
                                   rtol=1e-5)
        np.testing.assert_allclose(ka[key].stderr, kb[key].stderr,
                                   rtol=1e-4, atol=1e-9)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_query_batch_matches_sequential(use_pallas):
    tbl = table_lib.from_columns("s", synth.sessions_table(25_000, seed=4))
    cities = tbl.dictionaries["City"]
    queries = (
        [Query("s", AggOp.COUNT,
               predicate=Predicate.where(Atom("City", CmpOp.EQ, c)),
               bound=ErrorBound(0.1)) for c in cities[:4]]
        + [Query("s", AggOp.AVG, value_column="SessionTime",
                 group_by=("OS",), bound=ErrorBound(0.1)),
           Query("s", AggOp.SUM, value_column="Bitrate",
                 predicate=Predicate.where(Atom("OS", CmpOp.EQ, "os1"))),
           Query("s", AggOp.QUANTILE, value_column="SessionTime",
                 quantile=0.5,
                 predicate=Predicate.where(Atom("City", CmpOp.EQ, cities[0]))),
           ]
    )
    seq = [_db(tbl, use_pallas).query(q) for q in queries]  # fresh caches
    bat = _db(tbl, use_pallas).query_batch(queries)
    assert len(bat) == len(queries)
    for a, b in zip(seq, bat):
        _assert_answers_match(a, b)


def test_query_batch_disjunctive_and_warm_cache():
    tbl = table_lib.from_columns("s", synth.sessions_table(15_000, seed=6))
    cities = tbl.dictionaries["City"]
    q_or = Query("s", AggOp.COUNT, predicate=Predicate((
        Conjunction((Atom("City", CmpOp.EQ, cities[0]),)),
        Conjunction((Atom("City", CmpOp.EQ, cities[1]),)),
    )), bound=ErrorBound(0.2))
    db_seq, db_bat = _db(tbl), _db(tbl)
    want = db_seq.query(q_or)
    got = db_bat.query_batch([q_or])[0]
    assert {g.key for g in want.groups} == {g.key for g in got.groups}
    for gw, gg in zip(want.groups, got.groups):
        np.testing.assert_allclose(gw.estimate, gg.estimate, rtol=1e-5)
    # second batch hits the warm ELP + program caches and still agrees
    got2 = db_bat.query_batch([q_or])[0]
    for gw, gg in zip(want.groups, got2.groups):
        np.testing.assert_allclose(gw.estimate, gg.estimate, rtol=1e-5)


def test_query_batch_pallas_chunking(monkeypatch):
    """Groups larger than the per-scan cap split into chunked scans whose
    concatenated slices still match sequential answers."""
    from repro.core import engine as engine_mod
    monkeypatch.setattr(engine_mod, "_MAX_SCAN_BATCH", 2)
    tbl = table_lib.from_columns("s", synth.sessions_table(12_000, seed=9))
    cities = tbl.dictionaries["City"]
    queries = [Query("s", AggOp.COUNT,
                     predicate=Predicate.where(Atom("City", CmpOp.EQ, c)),
                     bound=ErrorBound(0.2)) for c in cities[:5]]
    seq = [_db(tbl, use_pallas=True).query(q) for q in queries]
    bat = _db(tbl, use_pallas=True).query_batch(queries)
    for a, b in zip(seq, bat):
        _assert_answers_match(a, b)


def test_query_batch_empty_and_single():
    tbl = table_lib.from_columns("s", synth.sessions_table(10_000, seed=8))
    db = _db(tbl)
    assert db.query_batch([]) == []
    q = Query("s", AggOp.COUNT, bound=ErrorBound(0.2))
    [got] = db.query_batch([q])
    want = _db(tbl).query(q)
    _assert_answers_match(want, got)
