"""Fault-injected serving: the chaos harness.

Covers the fault layer itself (deterministic plans, arming, spec semantics),
the replicated shard-partitioned scan (replica re-route bit-identity, HT
reweighting and CI widening under shard loss, poison containment), the
service degradation ladder (retry → replica → reweighted partial → stale
cache → typed error, plus deadline shedding and dispatcher-death safety),
the supervisor primitives, and a seeded chaos soak whose invariant is the
repo's serving contract under faults:

    every admitted query returns an Answer or raises a TYPED error;
    a returned Answer is either annotated degraded=True or numerically
    consistent with the fault-free answer; nothing hangs; and with an
    empty FaultPlan armed, answers are BIT-identical to the clean path.

`FAULT_SEEDS` (env) deepens the soak in CI; the local default keeps the
tier-1 suite fast.
"""
import os
import threading
import time

import numpy as np
import pytest

from repro.core import BlinkDB, EngineConfig
from repro.core import table as table_lib
from repro.core.estimators import GroupedMoments, reweight_moments
from repro.core.executor import merge_shard_reports, shard_of_strata, \
    ShardScanReport
from repro.data import synth
from repro.fault import inject
from repro.fault.inject import (AllShardsLostError, FaultError, FaultPlan,
                                FaultSpec, InjectedFault, arm, random_plan)
from repro.fault.supervisor import Heartbeat, RetryLoop
from repro.service import (AdmissionError, BlinkQLService, DeadlineShedError,
                           DegradedServiceError, ServiceConfig,
                           ServiceUnhealthyError, parse_blinkql)

N_SHARDS = 4  # EngineConfig default n_logical_shards


@pytest.fixture(scope="module")
def db():
    tbl = table_lib.from_columns("sessions",
                                 synth.sessions_table(20_000, seed=2))
    d = BlinkDB(EngineConfig(k1=400.0, m=3, seed=1))
    d.register_table("sessions", tbl)
    d.add_family("sessions", ("City",))
    d.add_family("sessions", ())
    return d


AVG_TXT = ("SELECT AVG(SessionTime) FROM sessions WHERE City = 'city003' "
           "ERROR WITHIN 10% CONFIDENCE 95%")


def _avg_q(db):
    return parse_blinkql(AVG_TXT, db).normalized()


def _assert_bit_identical(a, b):
    assert a.sample_phi == b.sample_phi
    assert a.sample_k == b.sample_k
    ka = {g.key: g for g in a.groups}
    kb = {g.key: g for g in b.groups}
    assert ka.keys() == kb.keys()
    for key in ka:
        assert ka[key].estimate == kb[key].estimate
        assert ka[key].stderr == kb[key].stderr
        assert ka[key].ci_low == kb[key].ci_low
        assert ka[key].ci_high == kb[key].ci_high


def _assert_close(a, b, rtol=1e-4):
    ka = {g.key: g for g in a.groups}
    kb = {g.key: g for g in b.groups}
    assert ka.keys() == kb.keys()
    for key in ka:
        np.testing.assert_allclose(ka[key].estimate, kb[key].estimate,
                                   rtol=rtol)


def _finite(ans):
    return all(np.isfinite(g.estimate) and np.isfinite(g.stderr)
               for g in ans.groups)


# ---------------------------------------------------------- fault layer

def test_fault_spec_validates_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(site="x", kind="explode")


def test_fault_spec_match_after_max_fires():
    spec = FaultSpec(site="s", kind="kill", match=(("shard", 1),),
                     after=1, max_fires=2)
    plan = FaultPlan([spec], seed=0)
    assert plan.visit("s", {"shard": 0}) == []      # no match
    assert plan.visit("other", {"shard": 1}) == []  # wrong site
    assert plan.visit("s", {"shard": 1}) == []      # after: skip first
    assert len(plan.visit("s", {"shard": 1})) == 1
    assert len(plan.visit("s", {"shard": 1})) == 1
    assert plan.visit("s", {"shard": 1}) == []      # max_fires exhausted
    assert plan.n_fires == 2
    assert plan.log == [("s", 0, "kill"), ("s", 0, "kill")]


def test_fault_plan_deterministic_under_fixed_visit_sequence():
    def fires(plan):
        out = []
        for v in range(20):
            out.append(bool(plan.visit("s", {"shard": v % 4})))
        return out

    mk = lambda: FaultPlan(
        [FaultSpec(site="s", kind="kill", p=0.5)], seed=42)
    assert fires(mk()) == fires(mk())


def test_random_plan_reproducible_and_bounded():
    a, b = random_plan(7), random_plan(7)
    assert a.specs == b.specs and a.seed == b.seed
    for seed in range(20):
        plan = random_plan(seed)
        assert 1 <= len(plan.specs) <= 5
        for spec in plan.specs:
            assert spec.site in ("shard.scan", "engine.scan")
            if spec.site == "engine.scan":   # bounded: retries must succeed
                assert spec.kind == "kill" and spec.max_fires <= 2


def test_arm_is_exclusive_and_scoped():
    assert inject.active() is None
    with arm(FaultPlan([FaultSpec(site="s", kind="kill")], seed=0)) as p:
        assert inject.active() is p
        with pytest.raises(RuntimeError, match="already armed"):
            with arm(FaultPlan()):
                pass
    assert inject.active() is None
    assert inject.site("s") is None   # disarmed: no-op


def test_site_kill_beats_poison_and_reports_context():
    plan = FaultPlan([FaultSpec(site="s", kind="poison"),
                      FaultSpec(site="s", kind="kill")], seed=0)
    with arm(plan):
        with pytest.raises(InjectedFault) as ei:
            inject.site("s", shard=3)
    assert ei.value.site == "s" and ei.value.context == {"shard": 3}
    assert isinstance(ei.value, FaultError)


# ------------------------------------------------- sharded scan (engine)

def test_empty_plan_is_bit_identical(db):
    q = _avg_q(db)
    clean = db.query(q)
    with arm(FaultPlan()):
        armed = db.query(q)
    _assert_bit_identical(clean, armed)
    assert not armed.degraded and armed.shards_total == 0


def test_empty_plan_batch_is_bit_identical(db):
    qs = [_avg_q(db),
          parse_blinkql("SELECT COUNT(SessionTime) FROM sessions "
                        "WHERE City = 'city001'", db).normalized()]
    clean = db.query_batch(list(qs))
    with arm(FaultPlan()):
        armed = db.query_batch(list(qs))
    for c, a in zip(clean, armed):
        _assert_bit_identical(c, a)


def test_sharded_clean_scan_matches_fused(db):
    """A non-empty plan that never fires engages the sharded path: the
    answer must agree numerically with the fused scan (different float
    summation order, so allclose not bitwise) and carry provenance."""
    q = _avg_q(db)
    clean = db.query(q)
    never = FaultPlan([FaultSpec(site="shard.scan", kind="kill",
                                 match=(("shard", 99),))], seed=0)
    with arm(never):
        sharded = db.query(q)
    _assert_close(clean, sharded, rtol=1e-4)
    assert not sharded.degraded
    assert sharded.shards_total == N_SHARDS and sharded.shards_lost == 0


def test_replica_reroute_is_bit_identical_to_sharded_clean(db):
    """Killing replica 0 of one shard re-routes to replica 1 — a
    deterministic re-execution of the SAME shard mask, so the final answer
    is bit-identical to the sharded scan with no faults at all."""
    q = _avg_q(db)
    never = FaultPlan([FaultSpec(site="shard.scan", kind="kill",
                                 match=(("shard", 99),))], seed=0)
    with arm(never):
        baseline = db.query(q)
    kill_r0 = FaultPlan([FaultSpec(site="shard.scan", kind="kill",
                                   match=(("shard", 1), ("replica", 0)))],
                        seed=0)
    with arm(kill_r0):
        rerouted = db.query(q)
    _assert_bit_identical(baseline, rerouted)
    assert not rerouted.degraded and rerouted.shards_lost == 0


def test_shard_loss_reweights_and_widens_ci(db):
    q = _avg_q(db)
    never = FaultPlan([FaultSpec(site="shard.scan", kind="kill",
                                 match=(("shard", 99),))], seed=0)
    with arm(never):
        baseline = db.query(q)
    lose_shard = FaultPlan([FaultSpec(site="shard.scan", kind="kill",
                                      match=(("shard", 1),))], seed=0)
    with arm(lose_shard):
        degraded = db.query(q)
    assert degraded.degraded
    assert degraded.shards_lost == 1 and degraded.shards_total == N_SHARDS
    assert _finite(degraded)
    kb = {g.key: g for g in baseline.groups}
    for g in degraded.groups:
        # The HT second-phase reweight strictly inflates variance: every
        # surviving group's stderr must be at least the clean stderr.
        assert g.stderr >= kb[g.key].stderr


def test_poison_is_detected_and_contained(db):
    """A poisoned replica produces NaN partials; the finiteness check must
    disqualify the attempt (never let NaNs reach the estimate) and fall to
    the replica / reweight rungs."""
    q = _avg_q(db)
    poison_all = FaultPlan([FaultSpec(site="shard.scan", kind="poison",
                                      match=(("shard", 2),))], seed=0)
    with arm(poison_all):
        ans = db.query(q)
    assert _finite(ans)
    assert ans.degraded and ans.shards_lost == 1


def test_all_shards_lost_raises_typed_error(db):
    q = _avg_q(db)
    kill_all = FaultPlan([FaultSpec(site="shard.scan", kind="kill")], seed=0)
    with arm(kill_all):
        with pytest.raises(AllShardsLostError):
            db.query(q)


def test_straggler_delay_is_tolerated(db):
    """A delay fault alone (no deadline configured) only slows the scan —
    same answer, no degradation."""
    q = _avg_q(db)
    never = FaultPlan([FaultSpec(site="shard.scan", kind="kill",
                                 match=(("shard", 99),))], seed=0)
    with arm(never):
        baseline = db.query(q)
    slow = FaultPlan([FaultSpec(site="shard.scan", kind="delay",
                                match=(("shard", 0),), delay_s=0.01)], seed=0)
    with arm(slow):
        delayed = db.query(q)
    _assert_bit_identical(baseline, delayed)
    assert not delayed.degraded


def test_shard_partition_is_disjoint_and_total():
    strata = np.arange(1000, dtype=np.int64)
    shards = shard_of_strata(strata, 4)
    assert shards.min() >= 0 and shards.max() < 4
    # every stratum lands in exactly one shard; the hash spreads them
    counts = np.bincount(shards, minlength=4)
    assert counts.sum() == 1000 and (counts > 0).all()


def test_reweight_moments_matches_hand_computation():
    mom = GroupedMoments(
        n=np.array([10.0]), wsum=np.array([20.0]), wxsum=np.array([40.0]),
        wx2sum=np.array([100.0]), var_count=np.array([4.0]),
        var_sum=np.array([8.0]), var_sum2=np.array([24.0]))
    f = 2.0
    out = reweight_moments(mom, f)
    # point leaves scale by f (HT with composed rate r/f); n is a raw count
    np.testing.assert_allclose(np.asarray(out.n), [10.0])
    np.testing.assert_allclose(np.asarray(out.wsum), [40.0])
    np.testing.assert_allclose(np.asarray(out.wxsum), [80.0])
    np.testing.assert_allclose(np.asarray(out.wx2sum), [200.0])
    # var' = f²·var + f(f−1)·(matching weighted leaf)
    np.testing.assert_allclose(np.asarray(out.var_count),
                               [4 * 4.0 + 2 * 20.0])
    np.testing.assert_allclose(np.asarray(out.var_sum),
                               [4 * 8.0 + 2 * 40.0])
    np.testing.assert_allclose(np.asarray(out.var_sum2),
                               [4 * 24.0 + 2 * 100.0])


def test_merge_shard_reports():
    a = ShardScanReport(n_shards=4, lost=(1,), rerouted=(), reweight=4 / 3)
    b = ShardScanReport(n_shards=4, lost=(2,), rerouted=(0,), reweight=1.0)
    m = merge_shard_reports([a, None, b])
    assert m.n_shards == 4 and set(m.lost) == {1, 2}
    assert m.rerouted == (0,) and m.reweight == pytest.approx(4 / 3)
    assert m.degraded
    assert merge_shard_reports([None, None]) is None


# ------------------------------------------------ degradation ladder

def test_service_retry_absorbs_bounded_engine_kill(db):
    svc = BlinkQLService(db, config=ServiceConfig(use_cache=False))
    try:
        plan = FaultPlan([FaultSpec(site="engine.scan", kind="kill",
                                    max_fires=1)], seed=0)
        with arm(plan):
            ans = svc.submit(AVG_TXT)
        assert ans.groups and not ans.degraded
        assert plan.n_fires == 1
    finally:
        svc.close()


def test_service_serves_stale_answer_with_declared_staleness(db):
    svc = BlinkQLService(db)
    try:
        warm = svc.submit(AVG_TXT)
        # Invalidate (family-set bump): entries demote to the stale store.
        svc.cache._on_invalidate("sessions", None)
        with arm(FaultPlan([FaultSpec(site="engine.scan",
                                      kind="kill")], seed=0)):
            stale = svc.submit(AVG_TXT)
        assert stale.degraded and stale.staleness_s > 0.0
        _assert_bit_identical(warm, stale)
        assert svc.n_stale == 1
        # Disarmed again: live execution resumes, the answer is fresh, and
        # the degraded serve must NOT have been cached as current.
        fresh = svc.submit(AVG_TXT)
        assert not fresh.degraded and fresh.staleness_s == 0.0
    finally:
        svc.close()


def test_service_degraded_error_when_ladder_exhausted(db):
    svc = BlinkQLService(db, config=ServiceConfig(use_cache=False))
    try:
        with arm(FaultPlan([FaultSpec(site="engine.scan",
                                      kind="kill")], seed=0)):
            with pytest.raises(DegradedServiceError) as ei:
                svc.submit(AVG_TXT)
        assert isinstance(ei.value.__cause__, InjectedFault)
    finally:
        svc.close()


def test_service_passes_degraded_shard_answer_and_never_caches_it(db):
    svc = BlinkQLService(db)
    try:
        with arm(FaultPlan([FaultSpec(site="shard.scan", kind="kill",
                                      match=(("shard", 1),))], seed=0)):
            ans = svc.submit(AVG_TXT)
        assert ans.degraded and ans.shards_lost == 1
        assert svc.n_degraded == 1
        assert svc.cache.get(_avg_q(db)) is None
        # The same query re-submitted fault-free executes fresh (no echo of
        # the degraded answer) and caches normally.
        clean = svc.submit(AVG_TXT)
        assert not clean.degraded
        assert svc.cache.get(_avg_q(db)) is not None
    finally:
        svc.close()


def test_service_nonfault_errors_propagate_unretried(db):
    """A ValueError (malformed query semantics) is not transient: it must
    reach the submitter unchanged on the FIRST attempt, not burn retries
    or degrade into a stale serve."""
    city = "city001"
    svc = BlinkQLService(db)
    try:
        with pytest.raises(ValueError, match="additive"):
            svc.submit(f"SELECT AVG(SessionTime) FROM sessions WHERE "
                       f"City = '{city}' OR OS = 'os2'")
    finally:
        svc.close()


def test_service_sheds_unmeetable_deadlines(db):
    svc = BlinkQLService(db, config=ServiceConfig(use_cache=False,
                                                  solo_bypass=False))
    try:
        svc.submit("SELECT COUNT(SessionTime) FROM sessions "
                   "WITHIN 5 SECONDS")          # prime the EWMA
        svc._exec_ewma = 10.0                   # simulate a saturated engine
        with pytest.raises(DeadlineShedError):
            svc.submit("SELECT COUNT(SessionTime) FROM sessions "
                       "WHERE City = 'city001' WITHIN 0.05 SECONDS")
        assert isinstance(DeadlineShedError("x"), AdmissionError)
        assert svc.stats()["shed"] == 1
        # ERROR-bound queries are never shed (no deadline to miss).
        ans = svc.submit(AVG_TXT)
        assert ans.groups
    finally:
        svc.close()


# ------------------------------------------------ dispatcher-death safety

def test_dispatcher_death_fails_pending_and_marks_unhealthy(db):
    svc = BlinkQLService(db, config=ServiceConfig(use_cache=False,
                                                  solo_bypass=False))
    with arm(FaultPlan([FaultSpec(site="scheduler.dispatch",
                                  kind="kill")], seed=0)):
        with pytest.raises(ServiceUnhealthyError) as ei:
            svc.submit(AVG_TXT, timeout=30)
        assert isinstance(ei.value.__cause__, InjectedFault)
        assert not svc.healthy
        # Later submissions are rejected at admission — typed, immediate.
        with pytest.raises(ServiceUnhealthyError):
            svc.submit(AVG_TXT)
        with pytest.raises(ServiceUnhealthyError):
            svc.submit_async(AVG_TXT)
    assert svc.stats()["healthy"] is False
    svc.close()   # dead dispatcher joins immediately; close() must not hang


def test_dispatcher_death_fails_queued_requests_from_other_threads(db):
    svc = BlinkQLService(db, config=ServiceConfig(use_cache=False,
                                                  solo_bypass=False))
    errors = []

    def session():
        try:
            svc.submit(AVG_TXT, timeout=30)
        except BaseException as e:   # noqa: BLE001
            errors.append(e)

    # Kill the SECOND dispatch iteration so requests queued while the first
    # batch executes are drained by the death handler, not the dispatcher.
    with arm(FaultPlan([FaultSpec(site="scheduler.dispatch", kind="kill",
                                  after=1)], seed=0)):
        threads = [threading.Thread(target=session) for _ in range(3)]
        # Stagger: let the dispatcher collect a SOLO first batch before the
        # rest submit, so the later requests are guaranteed to be queued (or
        # admitted post-death) when the second dispatch iteration is killed —
        # simultaneous starts can coalesce all three into batch one and the
        # kill site is then never reached inside the armed window.
        threads[0].start()
        time.sleep(0.2)
        for t in threads[1:]:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not any(t.is_alive() for t in threads), "a session hung"
    assert errors and all(isinstance(e, ServiceUnhealthyError)
                          for e in errors)
    svc.close()


# ------------------------------------------------ submit timeout races

def test_submit_timeout_frees_slot_and_service_recovers(db):
    svc = BlinkQLService(db, config=ServiceConfig(use_cache=False,
                                                  solo_bypass=False))
    orig = db.query_batch

    def slow(qs, **kw):
        time.sleep(0.4)
        return orig(qs, **kw)

    try:
        db.query_batch = slow
        with pytest.raises(TimeoutError):
            svc.submit(AVG_TXT, timeout=0.05)
        db.query_batch = orig
        # The abandoned request's slot is freed and the dispatcher drains:
        # the service answers normally afterwards.
        ans = svc.submit(AVG_TXT, timeout=30)
        assert ans.groups
        assert len(svc._queue) == 0
    finally:
        db.query_batch = orig
        svc.close()


def test_submit_timeout_with_solo_bypass_enabled(db):
    """A timed submit must take the queued path even when the bypass is on
    (inline execution cannot honor a caller timeout), so the timeout
    contract holds — and afterwards the bypass still serves solo traffic."""
    svc = BlinkQLService(db, config=ServiceConfig(use_cache=False,
                                                  solo_bypass=True))
    orig = db.query_batch

    def slow(qs, **kw):
        time.sleep(0.4)
        return orig(qs, **kw)

    try:
        db.query_batch = slow
        with pytest.raises(TimeoutError):
            svc.submit(AVG_TXT, timeout=0.05)
        db.query_batch = orig
        nb0 = svc.n_batches
        ans = svc.submit(AVG_TXT)           # untimed: bypass eligible again
        assert ans.groups and len(svc._queue) == 0
        assert svc.n_batches > nb0
    finally:
        db.query_batch = orig
        svc.close()


def test_concurrent_submit_timeouts_leak_nothing(db):
    svc = BlinkQLService(db, config=ServiceConfig(use_cache=False,
                                                  solo_bypass=False))
    orig = db.query_batch

    def slow(qs, **kw):
        time.sleep(0.4)
        return orig(qs, **kw)

    outcomes = []

    def session(i):
        try:
            outcomes.append(svc.submit(AVG_TXT, timeout=0.05))
        except TimeoutError:
            outcomes.append("timeout")

    try:
        db.query_batch = slow
        threads = [threading.Thread(target=session, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not any(t.is_alive() for t in threads), "a session hung"
        assert len(outcomes) == 4
        db.query_batch = orig
        # No leaked queue slots or wedged dispatcher.
        ans = svc.submit(AVG_TXT, timeout=30)
        assert ans.groups and len(svc._queue) == 0
    finally:
        db.query_batch = orig
        svc.close()


# ------------------------------------------------ async / batched submit

def test_submit_many_lands_in_one_batch(db):
    svc = BlinkQLService(db, config=ServiceConfig(use_cache=False,
                                                  solo_bypass=False))
    try:
        texts = [f"SELECT COUNT(SessionTime) FROM sessions "
                 f"WHERE City = 'city{i:03d}'" for i in range(6)]
        nb0 = svc.n_batches
        res = svc.submit_many(texts, timeout=120)
        assert len(res) == 6 and all(r.groups for r in res)
        assert svc.n_batches - nb0 == 1, \
            "an atomically admitted batch must coalesce into ONE scan"
        assert svc.n_queries == 6
    finally:
        svc.close()


def test_submit_async_future_and_cache_hit(db):
    svc = BlinkQLService(db)
    try:
        fut = svc.submit_async(AVG_TXT)
        ans = fut.result(timeout=120)
        assert ans.groups
        # Second submission hits the cache: the future resolves immediately.
        fut2 = svc.submit_async(AVG_TXT)
        assert fut2.done()
        _assert_bit_identical(ans, fut2.result())
    finally:
        svc.close()


def test_submit_many_mixed_errors_reach_only_their_query(db):
    svc = BlinkQLService(db, config=ServiceConfig(use_cache=False,
                                                  solo_bypass=False))
    try:
        bad = ("SELECT AVG(SessionTime) FROM sessions "
               "WHERE City = 'city001' OR OS = 'os2'")
        with pytest.raises(ValueError, match="additive"):
            svc.submit_many([AVG_TXT, bad], timeout=120)
        # The well-formed query in the same batch was still answered.
        assert svc.n_queries >= 1
        ans = svc.submit(AVG_TXT, timeout=120)
        assert ans.groups
    finally:
        svc.close()


# ------------------------------------------------ supervisor primitives

def test_heartbeat_stamps_each_worker_individually(monkeypatch):
    from repro.fault import supervisor as sup
    ticks = iter(range(100))
    monkeypatch.setattr(sup.time, "time", lambda: float(next(ticks)))
    hb = Heartbeat(n_workers=3)
    # One time() call per worker: distinct construction stamps, so the
    # first-deadline clock starts per worker, not at a shared instant.
    assert len(set(hb.last_time.tolist())) == 3


def test_retry_loop_no_sleep_after_final_attempt(monkeypatch):
    from repro.fault import supervisor as sup
    sleeps = []
    monkeypatch.setattr(sup.time, "sleep", sleeps.append)
    calls = []

    def boom():
        calls.append(1)
        raise RuntimeError("nope")

    with pytest.raises(RuntimeError, match="after 2 retries"):
        RetryLoop(max_retries=2, backoff_s=0.1).run(boom)
    assert len(calls) == 3              # initial + 2 retries
    assert sleeps == [0.1, 0.2]         # exponential, none after the last


def test_retry_loop_raise_last_reraises_original(monkeypatch):
    from repro.fault import supervisor as sup
    monkeypatch.setattr(sup.time, "sleep", lambda s: None)
    original = FloatingPointError("nan")

    def boom():
        raise original

    with pytest.raises(FloatingPointError) as ei:
        RetryLoop(max_retries=1, raise_last=True).run(boom)
    assert ei.value is original


def test_retry_loop_nontransient_propagates_immediately():
    calls = []

    def boom():
        calls.append(1)
        raise ValueError("not transient")

    with pytest.raises(ValueError):
        RetryLoop(max_retries=5).run(boom)
    assert len(calls) == 1


def test_retry_loop_retry_on_is_injectable(monkeypatch):
    from repro.fault import supervisor as sup
    monkeypatch.setattr(sup.time, "sleep", lambda s: None)
    attempts = []

    def flaky():
        attempts.append(1)
        if len(attempts) < 3:
            raise KeyError("transient here")
        return "ok"

    assert RetryLoop(max_retries=3, retry_on=(KeyError,)).run(flaky) == "ok"
    assert len(attempts) == 3


# ------------------------------------------------------- chaos soak

FAULT_SEEDS = int(os.environ.get("FAULT_SEEDS", "4"))


@pytest.mark.parametrize("seed", range(FAULT_SEEDS))
def test_chaos_soak(db, seed):
    """The serving contract under a random bounded fault schedule: every
    submission returns an Answer or raises a TYPED error; non-degraded
    answers agree with the fault-free reference; degraded answers are
    finite and annotated; no session hangs."""
    texts = [f"SELECT AVG(SessionTime) FROM sessions WHERE "
             f"City = 'city{i:03d}' ERROR WITHIN 10% CONFIDENCE 95%"
             for i in range(4)]
    reference = {t: db.query(parse_blinkql(t, db).normalized())
                 for t in texts}
    typed = (FaultError, DegradedServiceError, AdmissionError,
             ServiceUnhealthyError, TimeoutError)
    svc = BlinkQLService(db, config=ServiceConfig(use_cache=False,
                                                  retry_backoff_s=0.001))
    results: list = []

    def session(worker):
        for j, t in enumerate(texts):
            try:
                results.append((t, svc.submit(t, timeout=120)))
            except typed as e:
                results.append((t, e))

    try:
        with arm(random_plan(seed)):
            threads = [threading.Thread(target=session, args=(w,))
                       for w in range(3)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=300)
            assert not any(t.is_alive() for t in threads), "a session hung"
    finally:
        svc.close()

    assert len(results) == 3 * len(texts)
    for text, res in results:
        if isinstance(res, BaseException):
            continue                     # typed failure: contract satisfied
        assert _finite(res), "non-finite estimate escaped the fault layer"
        if not res.degraded:
            _assert_close(reference[text], res, rtol=1e-3)
        else:
            assert res.shards_lost > 0 or res.staleness_s > 0.0
