"""Multi-device behaviour via subprocesses (XLA_FLAGS device-count override
must be set before jax import, so each case runs in a fresh interpreter —
conftest/pyproject never set it globally)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_blinkdb_sharded_query_matches_single_device():
    """The shard_map executor over an 8-device data mesh returns the same
    moments as the single-device vmap path."""
    run_py("""
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import (AggOp, Atom, BlinkDB, CmpOp, EngineConfig,
                            ErrorBound, Query, Predicate)
    from repro.core import table as table_lib
    from repro.data import synth

    assert jax.device_count() == 8
    tbl = table_lib.from_columns("s", synth.sessions_table(40_000, seed=2))
    q = Query("s", AggOp.AVG, value_column="SessionTime", group_by=("OS",),
              bound=ErrorBound(0.1, 0.95))

    results = {}
    for name, mesh in [("single", None),
                       ("mesh8", jax.make_mesh((8,), ("data",)))]:
        db = BlinkDB(EngineConfig(k1=800.0, m=3, seed=3), mesh=mesh)
        db.register_table("s", tbl)
        db.add_family("s", ("OS",))
        db.add_family("s", ())
        ans = db.query(q)
        results[name] = {g.key: g.estimate for g in ans.groups}
    assert results["single"].keys() == results["mesh8"].keys()
    for k in results["single"]:
        np.testing.assert_allclose(results["single"][k], results["mesh8"][k],
                                   rtol=1e-3)
    print("OK")
    """)


def test_train_step_dp_tp_mesh_runs_and_matches():
    """One real train step on a 2x4 (data, model) mesh: loss matches the
    single-device step bitwise-closely, params update."""
    run_py("""
    import dataclasses
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import model as model_lib
    from repro.sharding import rules as rules_lib
    from repro.train import optim as optim_lib
    from repro.train import step as step_lib

    jax.config.update("jax_default_matmul_precision", "float32")
    cfg = get_config("qwen2-1.5b").reduced()
    cfg = dataclasses.replace(cfg, vocab_size=64, d_ff=64)
    params, axes = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = optim_lib.OptConfig(lr=1e-2, warmup_steps=1, decay_steps=10)
    opt = optim_lib.init_opt_state(params, opt_cfg)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 64, (8, 16)).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, 64, (8, 16)).astype(np.int32)),
    }
    step_cfg = step_lib.StepConfig(remat=False)
    fn = step_lib.make_train_step(cfg, opt_cfg, step_cfg)

    p1, o1, m1 = jax.jit(fn)(params, opt, batch)   # single device

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = rules_lib.default_rules(attn_dp=True)
    sh = step_lib.build_shardings(cfg, mesh, rules, step_cfg, opt_cfg)
    p_sh = jax.device_put(params, sh["params_sharding"])
    o_sh = jax.device_put(opt, sh["opt_sharding"])
    with rules_lib.activate(mesh, rules):
        p2, o2, m2 = jax.jit(fn)(p_sh, o_sh, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=5e-3)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                               rtol=2e-2)
    # Behavioural check (Adam at step1 is signSGD: bitwise param comparison
    # is meaningless under bf16 reduction-order changes): training on the
    # mesh must reduce the loss over a few repeated steps.
    with rules_lib.activate(mesh, rules):
        jfn = jax.jit(fn, donate_argnums=(0, 1))
        losses = [float(m2["loss"])]
        for _ in range(4):
            p2, o2, m2 = jfn(p2, o2, batch)
            losses.append(float(m2["loss"]))
    assert losses[-1] < losses[0], f"mesh training diverged {losses}"
    print("OK")
    """)


def test_elastic_checkpoint_reshard():
    """Save on a 4-shard layout, restore onto 8 shards (elastic restart)."""
    run_py("""
    import tempfile
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.ckpt import CheckpointManager

    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mesh4 = jax.make_mesh((4,), ("data",))
    sharded = jax.device_put(state["w"], NamedSharding(mesh4, P("data")))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(1, {"w": sharded})
        mesh8 = jax.make_mesh((8,), ("data",))
        target = NamedSharding(mesh8, P("data"))
        step, restored = mgr.restore({"w": state["w"]},
                                     shardings={"w": target})
        assert step == 1
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))
        assert restored["w"].sharding.num_devices == 8
    print("OK")
    """)


def test_decode_step_sharded_cache():
    """Decode with a KV cache sharded over a (2,2) mesh stays correct."""
    run_py("""
    import dataclasses
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.cells import build_cell
    from repro.models import model as model_lib
    from repro.sharding import rules as rules_lib

    cfg = get_config("qwen2-1.5b").reduced()
    params, axes = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    b, max_len = 4, 32
    caches = model_lib.init_cache(cfg, b, max_len, dtype=jnp.float32)
    tok = jnp.zeros((b, 1), jnp.int32)
    ref_next, _ = model_lib.decode_step(params, cfg, tok, caches,
                                        jnp.int32(0),
                                        compute_dtype=jnp.float32)

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rules = rules_lib.default_rules(attn_dp=True)
    c_axes = model_lib.cache_axes(cfg)
    cache_sh = rules_lib.tree_shardings(mesh, rules, c_axes, caches)
    caches2 = jax.tree.map(jax.device_put, caches, cache_sh)
    with rules_lib.activate(mesh, rules):
        got_next, _ = jax.jit(
            lambda p, t, c: model_lib.decode_step(p, cfg, t, c, jnp.int32(0),
                                                  compute_dtype=jnp.float32)
        )(params, tok, caches2)
    np.testing.assert_array_equal(np.asarray(ref_next), np.asarray(got_next))
    print("OK")
    """, devices=4)
